//! NSGA-II multi-objective genetic algorithm (Deb et al. 2002) over
//! integer decision vectors — the optimizer the paper uses (via pymoo) to
//! find Pareto-optimal partitioning points (§IV: "we use the NSGA-II to
//! determine Pareto-optimal points [...] the partitioning point serves as
//! variable of the partitioning problem. Since the complexity of a DNN
//! varies significantly, the population size as well as the number of
//! generations is set depending on the number of layers").
//!
//! Implements fast non-dominated sorting, crowding distance, binary
//! tournament selection with constrained domination (feasible solutions
//! dominate infeasible ones; infeasible ones compare by violation), and
//! integer crossover/mutation operators.
//!
//! Parallelism: candidate evaluation is the dominant cost in the DSE, so
//! [`optimize_par`] shards each generation's evaluations across scoped
//! workers. Genome construction (every RNG draw) stays on the
//! coordinator thread and fitness evaluation consumes no randomness, so
//! the evolution — and therefore the final front — is bit-identical for
//! every worker count.

use crate::util::parallel::par_map_with_pool;
use crate::util::rng::Pcg32;
use std::collections::{HashMap, HashSet};

/// Evaluation of one candidate.
#[derive(Debug, Clone, PartialEq)]
pub struct Eval {
    /// Objective values, all minimized.
    pub objectives: Vec<f64>,
    /// Constraint violation; 0.0 = feasible.
    pub violation: f64,
}

impl Eval {
    /// A feasible evaluation (violation 0).
    pub fn feasible(objectives: Vec<f64>) -> Self {
        Self { objectives, violation: 0.0 }
    }

    /// An infeasible evaluation ranked only by violation magnitude.
    pub fn infeasible(num_objectives: usize, violation: f64) -> Self {
        Self { objectives: vec![f64::INFINITY; num_objectives], violation: violation.max(f64::MIN_POSITIVE) }
    }

    /// True when no constraint is violated.
    pub fn is_feasible(&self) -> bool {
        self.violation == 0.0
    }
}

/// Problem definition over integer decision variables.
pub trait Problem {
    /// Per-worker reusable evaluation state: [`optimize_par`] creates
    /// one per worker via [`Self::make_scratch`], pools them across
    /// generations, and threads one through every evaluation a worker
    /// performs — so problems with expensive intermediate buffers (the
    /// explorer's `EvalScratch`) evaluate allocation-free in steady
    /// state over the whole run. Stateless problems use `()`. The
    /// scratch must never influence results: `evaluate` stays a pure
    /// function of the genome, and the run is bit-identical for every
    /// worker count. `Send`: worker states cross into scoped threads.
    type Scratch: Send;
    fn num_vars(&self) -> usize;
    fn num_objectives(&self) -> usize;
    /// Inclusive bounds for variable `i`.
    fn bounds(&self, i: usize) -> (i64, i64);
    /// Normalize a genome in place (e.g. sort partition points).
    fn repair(&self, _vars: &mut [i64]) {}
    /// Fresh per-worker scratch state.
    fn make_scratch(&self) -> Self::Scratch;
    /// Score a (repaired) genome; pure in `vars`, free to use `scratch`
    /// as reusable working memory.
    fn evaluate(&self, vars: &[i64], scratch: &mut Self::Scratch) -> Eval;
}

/// Algorithm configuration.
#[derive(Debug, Clone)]
pub struct Nsga2Cfg {
    /// Individuals per generation (kept even for pairing).
    pub population: usize,
    /// Number of generations to evolve.
    pub generations: usize,
    /// Per-child uniform-crossover probability.
    pub crossover_p: f64,
    /// Per-gene mutation probability.
    pub mutation_p: f64,
    /// RNG seed (full run is deterministic given it).
    pub seed: u64,
}

impl Nsga2Cfg {
    /// The paper scales population/generations with network depth; this
    /// mirrors pymoo-style defaults: pop ≈ 4·√L bounded to [20, 120],
    /// gens ≈ L/2 bounded to [30, 150].
    pub fn for_layers(layers: usize, seed: u64) -> Self {
        let pop = ((4.0 * (layers as f64).sqrt()) as usize).clamp(20, 120);
        let pop = pop + pop % 2; // even for pairwise crossover
        let generations = (layers / 2).clamp(30, 150);
        Self { population: pop, generations, crossover_p: 0.9, mutation_p: 0.2, seed }
    }
}

/// One individual of the final population.
#[derive(Debug, Clone)]
pub struct Solution {
    /// Decision variables of the solution.
    pub vars: Vec<i64>,
    /// Its objective values and violation.
    pub eval: Eval,
}

/// `a` constrained-dominates `b`.
pub fn dominates(a: &Eval, b: &Eval) -> bool {
    match (a.is_feasible(), b.is_feasible()) {
        (true, false) => true,
        (false, true) => false,
        (false, false) => a.violation < b.violation,
        (true, true) => {
            let mut strictly = false;
            for (x, y) in a.objectives.iter().zip(&b.objectives) {
                if x > y {
                    return false;
                }
                if x < y {
                    strictly = true;
                }
            }
            strictly
        }
    }
}

/// Both domination directions in one scan: `Greater` if `a` dominates
/// `b`, `Less` if `b` dominates `a`, `Equal` if incomparable. Agrees
/// with [`dominates`] in both directions (tested) but costs one pass
/// over the objectives instead of up to four.
fn dom_cmp(a: &Eval, b: &Eval) -> std::cmp::Ordering {
    use std::cmp::Ordering::*;
    match (a.is_feasible(), b.is_feasible()) {
        (true, false) => Greater,
        (false, true) => Less,
        (false, false) => {
            if a.violation < b.violation {
                Greater
            } else if b.violation < a.violation {
                Less
            } else {
                Equal
            }
        }
        (true, true) => {
            let (mut a_better, mut b_better) = (false, false);
            for (x, y) in a.objectives.iter().zip(&b.objectives) {
                if x < y {
                    a_better = true;
                } else if x > y {
                    b_better = true;
                }
            }
            match (a_better, b_better) {
                (true, false) => Greater,
                (false, true) => Less,
                _ => Equal,
            }
        }
    }
}

/// Fast non-dominated sort; returns fronts of indices (front 0 = best).
/// Single pass over unordered pairs via [`dom_cmp`] — ~4× fewer
/// objective scans than the naïve `dominates(i,j)`/`dominates(j,i)`
/// double loop, with identical fronts (same contents, same order).
pub fn non_dominated_sort(evals: &[Eval]) -> Vec<Vec<usize>> {
    let n = evals.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut counts = vec![0usize; n]; // number dominating i
    for i in 0..n {
        for j in i + 1..n {
            match dom_cmp(&evals[i], &evals[j]) {
                std::cmp::Ordering::Greater => {
                    dominated_by[i].push(j);
                    counts[j] += 1;
                }
                std::cmp::Ordering::Less => {
                    dominated_by[j].push(i);
                    counts[i] += 1;
                }
                std::cmp::Ordering::Equal => {}
            }
        }
    }
    let mut fronts = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| counts[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                counts[j] -= 1;
                if counts[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Crowding distance of each member of a front (boundaries = ∞).
pub fn crowding_distance(front: &[usize], evals: &[Eval]) -> Vec<f64> {
    let n = front.len();
    let mut dist = vec![0.0f64; n];
    if n <= 2 {
        return vec![f64::INFINITY; n];
    }
    let m = evals[front[0]].objectives.len();
    for obj in 0..m {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| {
            evals[front[a]].objectives[obj]
                .partial_cmp(&evals[front[b]].objectives[obj])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        dist[idx[0]] = f64::INFINITY;
        dist[idx[n - 1]] = f64::INFINITY;
        let lo = evals[front[idx[0]]].objectives[obj];
        let hi = evals[front[idx[n - 1]]].objectives[obj];
        let span = hi - lo;
        if span <= 0.0 || !span.is_finite() {
            continue;
        }
        for k in 1..n - 1 {
            let prev = evals[front[idx[k - 1]]].objectives[obj];
            let next = evals[front[idx[k + 1]]].objectives[obj];
            dist[idx[k]] += (next - prev) / span;
        }
    }
    dist
}

struct Individual {
    vars: Vec<i64>,
    eval: Eval,
    rank: usize,
    crowding: f64,
}

fn random_genome<P: Problem>(p: &P, rng: &mut Pcg32) -> Vec<i64> {
    let mut v: Vec<i64> = (0..p.num_vars())
        .map(|i| {
            let (lo, hi) = p.bounds(i);
            lo + rng.gen_range((hi - lo + 1) as u32) as i64
        })
        .collect();
    p.repair(&mut v);
    v
}

/// Uniform crossover + creep/reset mutation, then repair.
fn make_child<P: Problem>(p: &P, a: &[i64], b: &[i64], cfg: &Nsga2Cfg, rng: &mut Pcg32) -> Vec<i64> {
    let mut child: Vec<i64> = if rng.gen_bool(cfg.crossover_p) {
        a.iter().zip(b).map(|(&x, &y)| if rng.gen_bool(0.5) { x } else { y }).collect()
    } else {
        a.to_vec()
    };
    for i in 0..child.len() {
        if rng.gen_bool(cfg.mutation_p) {
            let (lo, hi) = p.bounds(i);
            if rng.gen_bool(0.5) {
                // Creep: small step, good for partition points on a chain.
                let span = ((hi - lo) / 10).max(1);
                let step = 1 + rng.gen_range(span as u32) as i64;
                child[i] = (child[i] + if rng.gen_bool(0.5) { step } else { -step }).clamp(lo, hi);
            } else {
                child[i] = lo + rng.gen_range((hi - lo + 1) as u32) as i64;
            }
        }
    }
    p.repair(&mut child);
    child
}

/// Binary tournament by (rank, crowding).
fn tournament<'a>(pop: &'a [Individual], rng: &mut Pcg32) -> &'a Individual {
    let a = &pop[rng.gen_usize(0, pop.len())];
    let b = &pop[rng.gen_usize(0, pop.len())];
    if a.rank < b.rank || (a.rank == b.rank && a.crowding > b.crowding) {
        a
    } else {
        b
    }
}

fn rank_population(pop: &mut Vec<Individual>, keep: usize) {
    let evals: Vec<Eval> = pop.iter().map(|i| i.eval.clone()).collect();
    let fronts = non_dominated_sort(&evals);
    let mut selected: Vec<Individual> = Vec::with_capacity(keep);
    let mut old: Vec<Option<Individual>> = std::mem::take(pop).into_iter().map(Some).collect();
    for (rank, front) in fronts.iter().enumerate() {
        let dist = crowding_distance(front, &evals);
        let mut members: Vec<(usize, f64)> = front.iter().copied().zip(dist).collect();
        // Fill whole fronts while they fit; sort the straddling front by
        // descending crowding distance.
        if selected.len() + members.len() > keep {
            members.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
        }
        for (idx, crowd) in members {
            if selected.len() >= keep {
                break;
            }
            let mut ind = old[idx].take().expect("front indices unique");
            ind.rank = rank;
            ind.crowding = crowd;
            selected.push(ind);
        }
        if selected.len() >= keep {
            break;
        }
    }
    *pop = selected;
}

/// Evaluate a batch of genomes (in parallel for `jobs > 1`) and wrap
/// them as unranked individuals, preserving genome order.
///
/// `memo` is the run-level genome→Eval cache: crossover/mutation
/// regenerate the same genomes constantly (especially late in a
/// converged run), and `Problem::evaluate` is pure, so each distinct
/// genome is evaluated exactly once per `optimize` call — duplicates
/// within a batch and across generations are free. Results are
/// bit-identical to evaluating every genome afresh.
///
/// `pool` holds the per-worker scratches, grown on demand and reused
/// across generations (worker `w` always gets `pool[w]`).
fn evaluate_batch<P: Problem + Sync>(
    problem: &P,
    genomes: Vec<Vec<i64>>,
    jobs: usize,
    memo: &mut HashMap<Vec<i64>, Eval>,
    pool: &mut Vec<P::Scratch>,
) -> Vec<Individual> {
    // Unique unseen genomes, in first-appearance order (deterministic).
    let mut need: Vec<Vec<i64>> = Vec::new();
    let mut queued: HashSet<&[i64]> = HashSet::new();
    for g in &genomes {
        if !memo.contains_key(g) && queued.insert(g.as_slice()) {
            need.push(g.clone());
        }
    }
    drop(queued);
    let workers = jobs.max(1).min(need.len().max(1));
    while pool.len() < workers {
        pool.push(problem.make_scratch());
    }
    let fresh =
        par_map_with_pool(jobs, &need, pool, |scratch, vars| problem.evaluate(vars, scratch));
    for (vars, eval) in need.into_iter().zip(fresh) {
        memo.insert(vars, eval);
    }
    genomes
        .into_iter()
        .map(|vars| {
            let eval = memo[&vars].clone();
            Individual { vars, eval, rank: 0, crowding: 0.0 }
        })
        .collect()
}

/// Run NSGA-II; returns the final population's first non-dominated front
/// (deduplicated by genome).
pub fn optimize<P: Problem + Sync>(problem: &P, cfg: &Nsga2Cfg) -> Vec<Solution> {
    optimize_par(problem, cfg, 1)
}

/// [`optimize`] with population evaluation sharded over `jobs` scoped
/// workers. Bit-identical to the serial run: all genome construction
/// happens on this thread in a fixed RNG sequence, and `evaluate` is a
/// pure function of the genome.
pub fn optimize_par<P: Problem + Sync>(problem: &P, cfg: &Nsga2Cfg, jobs: usize) -> Vec<Solution> {
    optimize_par_obs(problem, cfg, jobs, None)
}

/// Pre-fetched telemetry handles for one [`optimize_par_obs`] run, all
/// under the `nsga2.` prefix. Write-only from the GA's perspective —
/// no counter value ever feeds selection, ranking, or the RNG, which
/// is why instrumented runs stay bit-identical (`tests/obs.rs`).
struct GaObs {
    /// Fresh (memo-missing) genome evaluations (`nsga2.evals`).
    evals: crate::obs::CounterCell,
    /// Generations completed (`nsga2.generations`).
    generations: crate::obs::CounterCell,
    /// Worker scratch allocations; `evals - scratch_allocs` evaluations
    /// reused a pooled scratch (`nsga2.scratch_allocs`).
    scratch_allocs: crate::obs::CounterCell,
    /// Per-generation rank-0 front size (`nsga2.front_size`).
    front_size: std::sync::Arc<crate::obs::Histogram>,
}

/// [`optimize_par`] with optional telemetry: when `obs` carries a
/// registry, the run records fresh-evaluation counts, per-generation
/// front sizes, scratch-pool growth, and one wall-clock span per
/// generation. `None` is the zero-cost default; results are
/// bit-identical either way.
pub fn optimize_par_obs<P: Problem + Sync>(
    problem: &P,
    cfg: &Nsga2Cfg,
    jobs: usize,
    obs: Option<&crate::obs::Registry>,
) -> Vec<Solution> {
    assert!(cfg.population >= 4, "population too small");
    let cells = obs.map(|r| GaObs {
        evals: r.counter("nsga2.evals"),
        generations: r.counter("nsga2.generations"),
        scratch_allocs: r.counter("nsga2.scratch_allocs"),
        front_size: r.histogram("nsga2.front_size"),
    });
    let mut rng = Pcg32::new(cfg.seed, 0x6e73_6761); // "nsga"
    let mut memo: HashMap<Vec<i64>, Eval> = HashMap::new();
    let mut pool: Vec<P::Scratch> = Vec::new();
    let genomes: Vec<Vec<i64>> =
        (0..cfg.population).map(|_| random_genome(problem, &mut rng)).collect();
    let mut pop = evaluate_batch(problem, genomes, jobs, &mut memo, &mut pool);
    rank_population(&mut pop, cfg.population);
    if let Some(c) = &cells {
        c.evals.add(memo.len() as u64);
        c.scratch_allocs.add(pool.len() as u64);
    }

    for gen_idx in 0..cfg.generations {
        let start_ns = obs.map(|r| r.now_ns());
        let (evals_before, pool_before) = (memo.len(), pool.len());
        let mut children: Vec<Vec<i64>> = Vec::with_capacity(cfg.population);
        while children.len() < cfg.population {
            let a = tournament(&pop, &mut rng);
            let b = tournament(&pop, &mut rng);
            children.push(make_child(problem, &a.vars, &b.vars, cfg, &mut rng));
        }
        let offspring = evaluate_batch(problem, children, jobs, &mut memo, &mut pool);
        pop.extend(offspring);
        rank_population(&mut pop, cfg.population);
        if let (Some(c), Some(r)) = (&cells, obs) {
            c.generations.inc();
            c.evals.add((memo.len() - evals_before) as u64);
            c.scratch_allocs.add((pool.len() - pool_before) as u64);
            c.front_size.observe(pop.iter().filter(|i| i.rank == 0).count() as u64);
            r.wall_span(format!("nsga2 gen {gen_idx}"), 1, start_ns.unwrap_or(0));
        }
    }

    // Final front 0, deduplicated by genome.
    let mut out: Vec<Solution> = pop
        .into_iter()
        .filter(|i| i.rank == 0)
        .map(|i| Solution { vars: i.vars, eval: i.eval })
        .collect();
    out.sort_by(|a, b| a.vars.cmp(&b.vars));
    out.dedup_by(|a, b| a.vars == b.vars);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{property, Gen};

    /// Schaffer's problem N.1: minimize [x², (x-2)²]; Pareto set x∈[0,2].
    struct Schaffer;

    impl Problem for Schaffer {
        type Scratch = ();
        fn num_vars(&self) -> usize {
            1
        }
        fn num_objectives(&self) -> usize {
            2
        }
        fn bounds(&self, _: usize) -> (i64, i64) {
            (-1000, 1000)
        }
        fn make_scratch(&self) {}
        fn evaluate(&self, v: &[i64], _: &mut ()) -> Eval {
            let x = v[0] as f64 / 100.0;
            Eval::feasible(vec![x * x, (x - 2.0) * (x - 2.0)])
        }
    }

    #[test]
    fn schaffer_front_found() {
        let front = optimize(&Schaffer, &Nsga2Cfg::for_layers(60, 42));
        assert!(front.len() >= 10, "front too sparse: {}", front.len());
        for s in &front {
            let x = s.vars[0] as f64 / 100.0;
            assert!((-0.05..=2.05).contains(&x), "x={x} off the Pareto set");
        }
        // Coverage: both extremes approached.
        let xs: Vec<f64> = front.iter().map(|s| s.vars[0] as f64 / 100.0).collect();
        assert!(xs.iter().cloned().fold(f64::INFINITY, f64::min) < 0.3);
        assert!(xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max) > 1.7);
    }

    #[test]
    fn telemetry_does_not_perturb_the_front() {
        let reg = crate::obs::Registry::new();
        let cfg = Nsga2Cfg::for_layers(60, 42);
        let base = optimize(&Schaffer, &cfg);
        let instrumented = optimize_par_obs(&Schaffer, &cfg, 2, Some(&reg));
        assert_eq!(base.len(), instrumented.len());
        for (a, b) in base.iter().zip(&instrumented) {
            assert_eq!(a.vars, b.vars);
            for (x, y) in a.eval.objectives.iter().zip(&b.eval.objectives) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(reg.counter("nsga2.generations").get(), cfg.generations as u64);
        assert!(reg.counter("nsga2.evals").get() >= cfg.population as u64);
        assert_eq!(reg.histogram("nsga2.front_size").count(), cfg.generations as u64);
    }

    /// Constrained problem: x ≥ 300 infeasible.
    struct Constrained;

    impl Problem for Constrained {
        type Scratch = ();
        fn num_vars(&self) -> usize {
            1
        }
        fn num_objectives(&self) -> usize {
            2
        }
        fn bounds(&self, _: usize) -> (i64, i64) {
            (0, 1000)
        }
        fn make_scratch(&self) {}
        fn evaluate(&self, v: &[i64], _: &mut ()) -> Eval {
            if v[0] >= 300 {
                return Eval::infeasible(2, (v[0] - 299) as f64);
            }
            let x = v[0] as f64;
            Eval::feasible(vec![x, 299.0 - x])
        }
    }

    #[test]
    fn constraints_respected() {
        let front = optimize(&Constrained, &Nsga2Cfg::for_layers(40, 7));
        assert!(!front.is_empty());
        for s in &front {
            assert!(s.eval.is_feasible(), "infeasible solution in front: {:?}", s.vars);
            assert!(s.vars[0] < 300);
        }
    }

    #[test]
    fn dominates_rules() {
        let a = Eval::feasible(vec![1.0, 2.0]);
        let b = Eval::feasible(vec![2.0, 3.0]);
        let c = Eval::feasible(vec![2.0, 1.0]);
        let inf = Eval::infeasible(2, 5.0);
        let inf2 = Eval::infeasible(2, 1.0);
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&a, &c) && !dominates(&c, &a)); // incomparable
        assert!(!dominates(&a, &a)); // not strict
        assert!(dominates(&a, &inf));
        assert!(dominates(&inf2, &inf));
        assert!(!dominates(&inf, &a));
    }

    #[test]
    fn property_front0_is_truly_nondominated() {
        property("front 0 non-dominated", 60, |rng| {
            let n = Gen::usize_in(rng, 1..40);
            let evals: Vec<Eval> = (0..n)
                .map(|_| {
                    Eval::feasible(vec![
                        Gen::f64_in(rng, 0.0, 10.0),
                        Gen::f64_in(rng, 0.0, 10.0),
                    ])
                })
                .collect();
            let fronts = non_dominated_sort(&evals);
            // Every index appears exactly once.
            let total: usize = fronts.iter().map(|f| f.len()).sum();
            assert_eq!(total, n);
            // Nothing in front 0 is dominated by anything.
            for &i in &fronts[0] {
                for (j, e) in evals.iter().enumerate() {
                    if i != j {
                        assert!(!dominates(e, &evals[i]), "front-0 member dominated");
                    }
                }
            }
            // Each member of front k>0 is dominated by someone in front k-1.
            for k in 1..fronts.len() {
                for &i in &fronts[k] {
                    assert!(
                        fronts[k - 1].iter().any(|&j| dominates(&evals[j], &evals[i])),
                        "front {k} member not dominated by front {}",
                        k - 1
                    );
                }
            }
        });
    }

    #[test]
    fn property_dom_cmp_agrees_with_dominates() {
        use std::cmp::Ordering::*;
        property("dom_cmp == (dominates, dominates)", 200, |rng| {
            let gen_eval = |rng: &mut crate::util::rng::Pcg32| {
                if Gen::f64_in(rng, 0.0, 1.0) < 0.2 {
                    Eval::infeasible(2, Gen::f64_in(rng, 0.1, 5.0))
                } else {
                    // Small integer grid so ties/duplicates are common.
                    Eval::feasible(vec![
                        Gen::usize_in(rng, 0..4) as f64,
                        Gen::usize_in(rng, 0..4) as f64,
                    ])
                }
            };
            let a = gen_eval(rng);
            let b = gen_eval(rng);
            let expect = match (dominates(&a, &b), dominates(&b, &a)) {
                (true, false) => Greater,
                (false, true) => Less,
                (false, false) => Equal,
                (true, true) => unreachable!("domination is asymmetric"),
            };
            assert_eq!(dom_cmp(&a, &b), expect, "a={a:?} b={b:?}");
        });
    }

    #[test]
    fn memo_skips_duplicate_genomes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // A problem with a tiny genome space: duplicates are guaranteed,
        // and the memo must collapse them to one evaluation each.
        struct Counted(AtomicUsize);
        impl Problem for Counted {
            type Scratch = ();
            fn num_vars(&self) -> usize {
                1
            }
            fn num_objectives(&self) -> usize {
                2
            }
            fn bounds(&self, _: usize) -> (i64, i64) {
                (0, 9)
            }
            fn make_scratch(&self) {}
            fn evaluate(&self, v: &[i64], _: &mut ()) -> Eval {
                self.0.fetch_add(1, Ordering::Relaxed);
                let x = v[0] as f64;
                Eval::feasible(vec![x, 9.0 - x])
            }
        }
        let p = Counted(AtomicUsize::new(0));
        let cfg = Nsga2Cfg {
            population: 20,
            generations: 20,
            crossover_p: 0.9,
            mutation_p: 0.2,
            seed: 3,
        };
        let front = optimize(&p, &cfg);
        assert!(!front.is_empty());
        let evals = p.0.load(Ordering::Relaxed);
        assert!(evals <= 10, "10 distinct genomes but {evals} evaluations ran");
    }

    #[test]
    fn crowding_boundaries_infinite() {
        let evals = vec![
            Eval::feasible(vec![0.0, 4.0]),
            Eval::feasible(vec![1.0, 2.0]),
            Eval::feasible(vec![2.0, 1.0]),
            Eval::feasible(vec![4.0, 0.0]),
        ];
        let front: Vec<usize> = vec![0, 1, 2, 3];
        let d = crowding_distance(&front, &evals);
        assert!(d[0].is_infinite() && d[3].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
    }

    #[test]
    fn adaptive_config_scales() {
        let small = Nsga2Cfg::for_layers(10, 0);
        let big = Nsga2Cfg::for_layers(300, 0);
        assert!(small.population <= big.population);
        assert!(small.generations <= big.generations);
        assert_eq!(big.population % 2, 0);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = optimize(&Schaffer, &Nsga2Cfg::for_layers(30, 123));
        let b = optimize(&Schaffer, &Nsga2Cfg::for_layers(30, 123));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.vars, y.vars);
        }
    }

    #[test]
    fn parallel_evaluation_bit_identical_to_serial() {
        let cfg = Nsga2Cfg::for_layers(60, 321);
        let serial = optimize(&Schaffer, &cfg);
        for jobs in [2, 4, 7] {
            let par = optimize_par(&Schaffer, &cfg, jobs);
            assert_eq!(serial.len(), par.len(), "jobs={jobs}");
            for (x, y) in serial.iter().zip(&par) {
                assert_eq!(x.vars, y.vars, "jobs={jobs}");
                assert_eq!(x.eval.objectives, y.eval.objectives, "jobs={jobs}");
            }
        }
        // Constrained problems shard identically too.
        let c_serial = optimize(&Constrained, &Nsga2Cfg::for_layers(40, 7));
        let c_par = optimize_par(&Constrained, &Nsga2Cfg::for_layers(40, 7), 4);
        assert_eq!(c_serial.len(), c_par.len());
        for (x, y) in c_serial.iter().zip(&c_par) {
            assert_eq!(x.vars, y.vars);
        }
    }

    #[test]
    fn repair_is_applied() {
        struct Sorted;
        impl Problem for Sorted {
            type Scratch = ();
            fn num_vars(&self) -> usize {
                3
            }
            fn num_objectives(&self) -> usize {
                2
            }
            fn bounds(&self, _: usize) -> (i64, i64) {
                (0, 50)
            }
            fn repair(&self, v: &mut [i64]) {
                v.sort_unstable();
            }
            fn make_scratch(&self) {}
            fn evaluate(&self, v: &[i64], _: &mut ()) -> Eval {
                assert!(v.windows(2).all(|w| w[0] <= w[1]), "repair not applied");
                Eval::feasible(vec![v[0] as f64, -(v[2] as f64)])
            }
        }
        optimize(&Sorted, &Nsga2Cfg { population: 20, generations: 10, crossover_p: 0.9, mutation_p: 0.3, seed: 5 });
    }
}
