//! Dynamic batching: collect requests from a channel up to a batch-size
//! or time budget — the standard serving-system batcher, applied here to
//! the inference pipeline's stage inputs.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Outcome of one batch collection.
pub enum Batch<T> {
    /// One or more items (≤ max_batch).
    Items(Vec<T>),
    /// Upstream disconnected and drained.
    Closed,
}

/// Block for the first item, then drain greedily until `max_batch` items
/// or `max_wait` elapsed (whichever first). Never returns an empty batch.
pub fn collect<T>(rx: &Receiver<T>, max_batch: usize, max_wait: Duration) -> Batch<T> {
    assert!(max_batch >= 1);
    let first = match rx.recv() {
        Ok(item) => item,
        Err(_) => return Batch::Closed,
    };
    let mut items = vec![first];
    let deadline = Instant::now() + max_wait;
    while items.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            // Deadline passed: take whatever is already queued, no waiting.
            match rx.try_recv() {
                Ok(item) => items.push(item),
                Err(_) => break,
            }
            continue;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => items.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Batch::Items(items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        match collect(&rx, 4, Duration::from_millis(5)) {
            Batch::Items(items) => assert_eq!(items, vec![0, 1, 2, 3]),
            Batch::Closed => panic!("closed"),
        }
        match collect(&rx, 100, Duration::from_millis(5)) {
            Batch::Items(items) => assert_eq!(items.len(), 6),
            Batch::Closed => panic!("closed"),
        }
    }

    #[test]
    fn returns_closed_on_disconnect() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(matches!(collect(&rx, 4, Duration::from_millis(1)), Batch::Closed));
    }

    #[test]
    fn partial_batch_after_timeout() {
        let (tx, rx) = mpsc::channel();
        tx.send(1u32).unwrap();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(50));
            let _ = tx.send(2);
        });
        // Wait budget is 5 ms: the second item (at 50 ms) must miss it.
        match collect(&rx, 4, Duration::from_millis(5)) {
            Batch::Items(items) => assert_eq!(items, vec![1]),
            Batch::Closed => panic!("closed"),
        }
        t.join().unwrap();
    }

    #[test]
    fn blocks_for_first_item() {
        let (tx, rx) = mpsc::channel();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            tx.send(42u32).unwrap();
        });
        match collect(&rx, 4, Duration::from_millis(1)) {
            Batch::Items(items) => assert_eq!(items, vec![42]),
            Batch::Closed => panic!("closed"),
        }
        t.join().unwrap();
    }
}
