//! Dynamic batching: collect requests from a channel up to a batch-size
//! or time budget — the standard serving-system batcher, applied here to
//! the inference pipeline's stage inputs.
//!
//! The *policy* (when is a forming batch complete?) is factored out as
//! [`BatchPolicy`] so the wall-clock coordinator and the discrete-event
//! serving simulator (`crate::sim`) share one definition — the two
//! runtimes must agree on batching semantics for cross-validation to be
//! meaningful.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// The dynamic-batching policy shared by `coordinator` stage threads and
/// the `sim` stage servers: a batch closes when it is *full*
/// (`max_batch` items) or when the collection has *waited out its
/// budget* (`max_wait` since collection began), whichever comes first.
/// An empty batch never closes — both runtimes block/idle until the
/// first item arrives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Maximum items per batch (≥ 1).
    pub max_batch: usize,
    /// Maximum time to wait for more items after collection begins.
    pub max_wait: Duration,
}

impl BatchPolicy {
    /// Build a policy that closes batches at `max_batch` items or after `max_wait`.
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1, "max_batch must be at least 1");
        Self { max_batch, max_wait }
    }

    /// A batch of `len` items is full — closes regardless of elapsed
    /// time (and, for dispatchers, regardless of a pending wait timer).
    pub fn full(&self, len: usize) -> bool {
        len >= self.max_batch
    }

    /// The batch-close condition: `len` items collected, `waited`
    /// elapsed since collection began. Empty batches never close.
    pub fn closes(&self, len: usize, waited: Duration) -> bool {
        len > 0 && (self.full(len) || waited >= self.max_wait)
    }

    /// How many of `queued` waiting items one batch takes.
    pub fn take(&self, queued: usize) -> usize {
        queued.min(self.max_batch)
    }
}

impl Default for BatchPolicy {
    /// The coordinator's historical defaults (batch 8, 2 ms wait).
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Outcome of one batch collection.
pub enum Batch<T> {
    /// One or more items (≤ `policy.max_batch`).
    Items(Vec<T>),
    /// Upstream disconnected and drained.
    Closed,
}

/// Block for the first item, then drain greedily until the policy closes
/// the batch (full, or wait budget spent — whichever first). Never
/// returns an empty batch.
pub fn collect<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Batch<T> {
    assert!(policy.max_batch >= 1);
    let first = match rx.recv() {
        Ok(item) => item,
        Err(_) => return Batch::Closed,
    };
    let mut items = vec![first];
    let start = Instant::now();
    // Both exit conditions below ARE the shared policy — the sim engine
    // dispatches on the same `closes()`/`take()` calls, so changing the
    // policy changes both runtimes together.
    loop {
        // Full closes the batch regardless of time.
        if policy.full(items.len()) {
            break;
        }
        let waited = start.elapsed();
        if policy.closes(items.len(), waited) {
            // Wait budget spent: like the sim's batch-timeout path
            // (which `take`s everything queued), drain what is already
            // here without waiting for more.
            match rx.try_recv() {
                Ok(item) => items.push(item),
                Err(_) => break,
            }
            continue;
        }
        match rx.recv_timeout(policy.max_wait - waited) {
            Ok(item) => items.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Batch::Items(items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{property, Gen};
    use std::sync::mpsc;
    use std::thread;

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy::new(max_batch, Duration::from_millis(wait_ms))
    }

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        match collect(&rx, &policy(4, 5)) {
            Batch::Items(items) => assert_eq!(items, vec![0, 1, 2, 3]),
            Batch::Closed => panic!("closed"),
        }
        match collect(&rx, &policy(100, 5)) {
            Batch::Items(items) => assert_eq!(items.len(), 6),
            Batch::Closed => panic!("closed"),
        }
    }

    #[test]
    fn returns_closed_on_disconnect() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(matches!(collect(&rx, &policy(4, 1)), Batch::Closed));
    }

    #[test]
    fn partial_batch_after_timeout() {
        let (tx, rx) = mpsc::channel();
        tx.send(1u32).unwrap();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(50));
            let _ = tx.send(2);
        });
        // Wait budget is 5 ms: the second item (at 50 ms) must miss it.
        match collect(&rx, &policy(4, 5)) {
            Batch::Items(items) => assert_eq!(items, vec![1]),
            Batch::Closed => panic!("closed"),
        }
        t.join().unwrap();
    }

    #[test]
    fn blocks_for_first_item() {
        let (tx, rx) = mpsc::channel();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            tx.send(42u32).unwrap();
        });
        match collect(&rx, &policy(4, 1)) {
            Batch::Items(items) => assert_eq!(items, vec![42]),
            Batch::Closed => panic!("closed"),
        }
        t.join().unwrap();
    }

    #[test]
    fn zero_wait_budget_still_drains_queued_items() {
        // A spent (even zero) wait budget must not shrink batches to 1:
        // items already queued are taken up to max_batch, exactly like
        // the sim engine's batch-timeout dispatch.
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        match collect(&rx, &policy(8, 0)) {
            Batch::Items(items) => assert_eq!(items, vec![0, 1, 2, 3, 4, 5, 6, 7]),
            Batch::Closed => panic!("closed"),
        }
    }

    #[test]
    #[should_panic(expected = "max_batch")]
    fn zero_max_batch_rejected() {
        BatchPolicy::new(0, Duration::from_millis(1));
    }

    #[test]
    fn property_batch_close_conditions() {
        property("batch closes iff full or wait budget spent", 300, |rng| {
            let p = BatchPolicy::new(
                Gen::usize_in(rng, 1..64),
                Duration::from_micros(Gen::usize_in(rng, 1..10_000) as u64),
            );
            let len = Gen::usize_in(rng, 0..128);
            let waited = Duration::from_micros(Gen::usize_in(rng, 0..20_000) as u64);
            let closes = p.closes(len, waited);
            // Definition: nonempty AND (full OR budget spent).
            assert_eq!(closes, len > 0 && (len >= p.max_batch || waited >= p.max_wait));
            assert_eq!(p.full(len), len >= p.max_batch);
            // Fullness is the time-independent component of closes.
            if p.full(len) && len > 0 {
                assert!(p.closes(len, Duration::ZERO));
            }
            // Empty batches never close.
            assert!(!p.closes(0, waited));
            // Monotone in both arguments: once closed, more items or more
            // waiting cannot reopen it.
            if closes {
                assert!(p.closes(len + 1, waited));
                assert!(p.closes(len, waited + Duration::from_micros(1)));
            }
            // A full batch closes no matter how briefly it waited.
            assert!(p.closes(p.max_batch, Duration::ZERO));
            // The wait budget closes any nonempty batch.
            assert!(p.closes(1, p.max_wait));
            // `take` never exceeds the cap or the queue.
            let queued = Gen::usize_in(rng, 0..256);
            let take = p.take(queued);
            assert!(take <= p.max_batch && take <= queued);
            assert_eq!(take, queued.min(p.max_batch));
        });
    }
}
