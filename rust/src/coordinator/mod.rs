//! Pipeline coordinator: execute a partitioned inference schedule as an
//! asynchronous pipeline of platform workers connected by a (simulated)
//! link — the runtime counterpart of Definition 4.
//!
//! Each platform is a stage thread with a bounded input queue
//! (backpressure), a dynamic batcher, and a compute body: either real
//! AOT artifacts executed via PJRT, or a simulated stage (deterministic
//! latency per item) used by tests and latency-model ablations. The link
//! between stages is simulated by sleeping the modelled transfer time —
//! platforms in the paper's system are physically distinct, so
//! wall-clock sleep reproduces the pipelining behaviour faithfully.
//!
//! PJRT note: the `xla` crate's client is `Rc`-based and not `Send`, so
//! each stage thread builds its own `Engine` and compiles its artifacts
//! in-thread; nothing PJRT-related crosses a thread boundary. Real
//! artifact execution requires the `xla` cargo feature; without it,
//! artifact stages fail cleanly at realization time and every request
//! routed through them is marked failed (simulated stages always work).

pub mod batcher;
pub mod metrics;

pub use batcher::BatchPolicy;
pub use metrics::{Completion, PipelineReport, StageStats};

use crate::link::LinkModel;
use crate::runtime::ArtifactMeta;
#[cfg(feature = "xla")]
use crate::runtime::{Engine, Executable};
use anyhow::Result;
use batcher::Batch;
use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread;
use std::time::{Duration, Instant};

/// What a stage runs. Must be `Send` (constructed before the thread
/// spawns, realized inside it).
#[derive(Debug, Clone)]
pub enum StageComputeSpec {
    /// Compile these artifacts (same segment, different batch sizes) on
    /// the stage's own PJRT client.
    Artifacts { dir: PathBuf, metas: Vec<ArtifactMeta> },
    /// Deterministic fake compute: `base + per_item × n` latency,
    /// `out_elems` outputs per item (copied from the input, truncated or
    /// zero-padded). `fail_every` injects an error on every n-th batch.
    Simulated {
        base: Duration,
        per_item: Duration,
        out_elems: usize,
        fail_every: Option<u64>,
    },
}

/// One pipeline stage (= one platform of the chain).
#[derive(Debug, Clone)]
pub struct StageSpec {
    /// Stage display name.
    pub name: String,
    /// What the stage executes (artifacts or simulated compute).
    pub compute: StageComputeSpec,
    /// Payload bytes per item sent to the next stage (for link timing).
    pub out_bytes_per_item: u64,
}

/// Pipeline-wide configuration.
#[derive(Debug, Clone)]
pub struct PipelineCfg {
    /// Link model between consecutive stages.
    pub link: LinkModel,
    /// Dynamic-batching policy, shared with the serving simulator
    /// (`crate::sim`) so both runtimes batch identically.
    pub batch: BatchPolicy,
    /// Bounded queue depth between stages (backpressure).
    pub queue_depth: usize,
    /// Sleep the modelled link time (true for end-to-end measurements;
    /// false for pure compute benchmarks).
    pub simulate_link: bool,
}

impl Default for PipelineCfg {
    fn default() -> Self {
        Self {
            link: LinkModel::gigabit_ethernet(),
            batch: BatchPolicy::default(),
            queue_depth: 32,
            simulate_link: true,
        }
    }
}

/// Instantiate an explored candidate's stage plan
/// ([`crate::explorer::CandidateMetrics::plan`]) as simulated pipeline
/// stages — the wall-clock counterpart of
/// `sim::Deployment::from_candidate`, closing the explorer→coordinator
/// loop without AOT artifacts.
///
/// The coordinator executes a *linear* chain of stage threads, so
/// branch-parallel (DAG) plans are realized conservatively serialized
/// in platform order: pipelined throughput matches the plan (the
/// bottleneck stage is the same either way), while single-inference
/// latency is over-approximated by the stacked branches. Each stage
/// ships `Σ edges bytes × hops` downstream for link timing — multi-hop
/// transfers (idle platforms forwarding) are approximated by scaling
/// the payload, which is exact on the bandwidth term and undercounts
/// one per-transfer base latency per extra hop.
pub fn simulated_specs_from_plan(
    plan: &[crate::explorer::StagePlan],
    platform_names: &[String],
) -> Vec<StageSpec> {
    let n = plan.len();
    plan.iter()
        .enumerate()
        .map(|(i, p)| {
            let wire: u64 = p.edges.iter().map(|e| e.bytes.saturating_mul(e.hops)).sum();
            // Hand-built plans without explicit edges fall back to the
            // chain aggregates.
            let wire = if p.edges.is_empty() {
                p.out_bytes.saturating_mul(p.out_hops.max(1))
            } else {
                wire
            };
            StageSpec {
                name: platform_names
                    .get(p.platform)
                    .cloned()
                    .unwrap_or_else(|| format!("P{}", p.platform)),
                compute: StageComputeSpec::Simulated {
                    base: Duration::ZERO,
                    per_item: Duration::from_secs_f64(p.latency_s.max(0.0)),
                    out_elems: ((p.out_bytes / 4).max(1)) as usize,
                    fail_every: None,
                },
                // The last stage's egress (if any) leaves the pipeline;
                // the coordinator only times inter-stage transfers.
                out_bytes_per_item: if i + 1 < n { wire } else { 0 },
            }
        })
        .collect()
}

/// A request travelling through the pipeline.
#[derive(Debug)]
struct Item {
    id: u64,
    data: Vec<f32>,
    submitted: Instant,
    ok: bool,
}

enum StageBody {
    #[cfg(feature = "xla")]
    Real(Vec<Executable>),
    Sim { base: Duration, per_item: Duration, out_elems: usize, fail_every: Option<u64> },
}

impl StageBody {
    fn realize(spec: &StageComputeSpec) -> Result<Self> {
        match spec {
            #[cfg(feature = "xla")]
            StageComputeSpec::Artifacts { dir, metas } => {
                let engine = Engine::cpu()?;
                let mut exes: Vec<Executable> =
                    metas.iter().map(|m| engine.load(dir, m)).collect::<Result<_>>()?;
                exes.sort_by_key(|e| e.meta.batch);
                anyhow::ensure!(!exes.is_empty(), "stage has no artifacts");
                Ok(StageBody::Real(exes))
            }
            #[cfg(not(feature = "xla"))]
            StageComputeSpec::Artifacts { .. } => anyhow::bail!(
                "AOT artifact stages need the `xla` feature (cargo build --features xla)"
            ),
            StageComputeSpec::Simulated { base, per_item, out_elems, fail_every } => {
                Ok(StageBody::Sim {
                    base: *base,
                    per_item: *per_item,
                    out_elems: *out_elems,
                    fail_every: *fail_every,
                })
            }
        }
    }

    /// Run a batch; returns per-item outputs (empty on failure).
    fn run(&self, batch_no: u64, items: &[Item]) -> Result<Vec<Vec<f32>>> {
        match self {
            #[cfg(feature = "xla")]
            StageBody::Real(exes) => {
                let n = items.len();
                // Smallest artifact whose batch covers n; else chunk by
                // the largest.
                let exe = exes
                    .iter()
                    .find(|e| e.meta.batch >= n)
                    .unwrap_or_else(|| exes.last().unwrap());
                let chunk = exe.meta.batch;
                let mut outs = Vec::with_capacity(n);
                let mut i = 0;
                while i < n {
                    let take = chunk.min(n - i);
                    let mut flat = Vec::with_capacity(take * exe.input_elems());
                    for item in &items[i..i + take] {
                        flat.extend_from_slice(&item.data);
                    }
                    let out = exe.run_padded(&flat, take)?;
                    let per = exe.output_elems();
                    for j in 0..take {
                        outs.push(out[j * per..(j + 1) * per].to_vec());
                    }
                    i += take;
                }
                Ok(outs)
            }
            StageBody::Sim { base, per_item, out_elems, fail_every } => {
                if let Some(k) = fail_every {
                    if *k > 0 && batch_no % k == k - 1 {
                        anyhow::bail!("injected failure on batch {batch_no}");
                    }
                }
                thread::sleep(*base + per_item.mul_f64(items.len() as f64));
                Ok(items
                    .iter()
                    .map(|it| {
                        let mut v = it.data.clone();
                        v.resize(*out_elems, 0.0);
                        v
                    })
                    .collect())
            }
        }
    }
}

fn stage_thread(
    spec: StageSpec,
    cfg: PipelineCfg,
    rx: Receiver<Item>,
    tx: SyncSender<Item>,
    is_last: bool,
    ready: std::sync::Arc<std::sync::Barrier>,
) -> StageStats {
    let mut stats = StageStats { name: spec.name.clone(), ..Default::default() };
    let body = StageBody::realize(&spec.compute);
    // Artifact compilation happens above; the run clock starts once every
    // stage reaches this barrier, so setup cost is excluded from the
    // measured throughput (platforms in the modelled system are already
    // flashed before serving starts).
    ready.wait();
    let body = match body {
        Ok(b) => b,
        Err(e) => {
            // Cannot realize the stage: fail every item through.
            eprintln!("stage {}: {e:#}", spec.name);
            while let Ok(mut item) = rx.recv() {
                item.ok = false;
                if tx.send(item).is_err() {
                    break;
                }
            }
            return stats;
        }
    };
    let mut batch_no = 0u64;
    loop {
        let items = match batcher::collect(&rx, &cfg.batch) {
            Batch::Items(items) => items,
            Batch::Closed => break,
        };
        // Failed items pass through untouched; live ones get computed.
        let (mut failed, live): (Vec<Item>, Vec<Item>) =
            items.into_iter().partition(|i| !i.ok);
        let mut processed: Vec<Item> = Vec::with_capacity(live.len());
        if !live.is_empty() {
            let t0 = Instant::now();
            match body.run(batch_no, &live) {
                Ok(outs) => {
                    stats.batches += 1;
                    stats.items += live.len() as u64;
                    for (mut item, out) in live.into_iter().zip(outs) {
                        item.data = out;
                        processed.push(item);
                    }
                }
                Err(_) => {
                    stats.failures += live.len() as u64;
                    for mut item in live {
                        item.ok = false;
                        item.data.clear();
                        processed.push(item);
                    }
                }
            }
            stats.busy += t0.elapsed();
        }
        batch_no += 1;
        // Link transfer to the next stage (once per batch of payloads).
        if !is_last && cfg.simulate_link {
            let live_count = processed.iter().filter(|i| i.ok).count() as u64;
            let bytes = live_count * spec.out_bytes_per_item;
            if bytes > 0 {
                let d = Duration::from_secs_f64(cfg.link.latency_s(bytes));
                thread::sleep(d);
                stats.link += d;
            }
        }
        for item in processed.into_iter().chain(failed.drain(..)) {
            if tx.send(item).is_err() {
                return stats;
            }
        }
    }
    stats
}

/// Run `inputs` through the staged pipeline; blocks until every request
/// completes (or fails) and returns the full report.
pub fn run_pipeline(
    stages: Vec<StageSpec>,
    cfg: &PipelineCfg,
    inputs: Vec<Vec<f32>>,
) -> PipelineReport {
    assert!(!stages.is_empty(), "pipeline needs at least one stage");
    let n_stages = stages.len();
    let ready = std::sync::Arc::new(std::sync::Barrier::new(n_stages + 1));

    // Channel chain: injector -> s0 -> s1 -> ... -> collector.
    let mut senders: Vec<SyncSender<Item>> = Vec::with_capacity(n_stages + 1);
    let mut receivers: Vec<Receiver<Item>> = Vec::with_capacity(n_stages + 1);
    for _ in 0..=n_stages {
        let (tx, rx) = sync_channel::<Item>(cfg.queue_depth.max(1));
        senders.push(tx);
        receivers.push(rx);
    }
    // receivers[0] feeds stage 0; receivers[n] is the collector.
    let collector_rx = receivers.pop().unwrap();

    let mut handles = Vec::with_capacity(n_stages);
    // Iterate stages in reverse so each thread takes its own rx/tx pair.
    let mut rx_iter = receivers.into_iter();
    let mut tx_iter = senders.clone().into_iter().skip(1);
    for (idx, spec) in stages.into_iter().enumerate() {
        let rx = rx_iter.next().unwrap();
        let tx = tx_iter.next().unwrap();
        let cfg = cfg.clone();
        let is_last = idx == n_stages - 1;
        let ready = ready.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("stage-{}", spec.name))
                .spawn(move || stage_thread(spec, cfg, rx, tx, is_last, ready))
                .expect("spawn stage thread"),
        );
    }
    // Wait for every stage to finish compiling before starting the clock.
    ready.wait();
    let start = Instant::now();
    // Drop our copies of the inter-stage senders so channels close when
    // the upstream stage finishes.
    let injector = senders.remove(0);
    drop(senders);

    let total = inputs.len();
    let feeder = thread::spawn(move || {
        for (id, data) in inputs.into_iter().enumerate() {
            let item = Item { id: id as u64, data, submitted: Instant::now(), ok: true };
            if injector.send(item).is_err() {
                break;
            }
        }
        // Dropping the injector closes stage 0's input.
    });

    let mut completions = Vec::with_capacity(total);
    while let Ok(item) = collector_rx.recv() {
        let prediction = if item.ok && !item.data.is_empty() {
            Some(
                item.data
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap(),
            )
        } else {
            None
        };
        completions.push(Completion {
            id: item.id,
            latency: item.submitted.elapsed(),
            ok: item.ok,
            prediction,
        });
        if completions.len() == total {
            break;
        }
    }
    feeder.join().expect("feeder panicked");
    let stages_stats: Vec<StageStats> =
        handles.into_iter().map(|h| h.join().expect("stage panicked")).collect();
    completions.sort_by_key(|c| c.id);
    PipelineReport { completions, wall: start.elapsed(), stages: stages_stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{property, Gen};

    fn sim_stage(name: &str, per_item_us: u64, out_elems: usize) -> StageSpec {
        StageSpec {
            name: name.into(),
            compute: StageComputeSpec::Simulated {
                base: Duration::from_micros(50),
                per_item: Duration::from_micros(per_item_us),
                out_elems,
                fail_every: None,
            },
            out_bytes_per_item: 64,
        }
    }

    fn fast_cfg() -> PipelineCfg {
        PipelineCfg {
            batch: BatchPolicy::new(8, Duration::from_micros(200)),
            queue_depth: 8,
            simulate_link: false,
            ..Default::default()
        }
    }

    #[test]
    fn specs_from_plan_execute_on_the_coordinator() {
        use crate::explorer::{PlanEdge, StagePlan};
        // A branch-parallel plan (two stages with a fan-out edge set)
        // realizes as a serialized two-stage wall-clock pipeline.
        let plan = vec![
            StagePlan {
                platform: 0,
                latency_s: 20e-6,
                energy_j: 0.0,
                out_bytes: 128,
                out_hops: 1,
                edges: vec![PlanEdge { to: Some(1), bytes: 128, hops: 1 }],
                replicas: 1,
            },
            StagePlan {
                platform: 1,
                latency_s: 30e-6,
                energy_j: 0.0,
                out_bytes: 0,
                out_hops: 0,
                edges: Vec::new(),
                replicas: 1,
            },
        ];
        let names = vec!["A".to_string(), "B".to_string()];
        let specs = simulated_specs_from_plan(&plan, &names);
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "A");
        assert_eq!(specs[0].out_bytes_per_item, 128);
        assert_eq!(specs[1].out_bytes_per_item, 0, "tail egress leaves the pipeline");
        let inputs: Vec<Vec<f32>> = (0..16).map(|i| vec![i as f32; 8]).collect();
        let report = run_pipeline(specs, &fast_cfg(), inputs);
        assert_eq!(report.completed(), 16);
    }

    #[test]
    fn every_request_completes_exactly_once() {
        let inputs: Vec<Vec<f32>> = (0..40).map(|i| vec![i as f32; 8]).collect();
        let report = run_pipeline(
            vec![sim_stage("a", 20, 8), sim_stage("b", 20, 4)],
            &fast_cfg(),
            inputs,
        );
        assert_eq!(report.completions.len(), 40);
        assert_eq!(report.completed(), 40);
        let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn failure_injection_marks_requests_failed() {
        let mut stage_b = sim_stage("b", 10, 4);
        stage_b.compute = StageComputeSpec::Simulated {
            base: Duration::from_micros(10),
            per_item: Duration::from_micros(10),
            out_elems: 4,
            fail_every: Some(2), // every 2nd batch fails
        };
        let inputs: Vec<Vec<f32>> = (0..30).map(|_| vec![0.0; 8]).collect();
        let report =
            run_pipeline(vec![sim_stage("a", 10, 8), stage_b], &fast_cfg(), inputs);
        assert_eq!(report.completions.len(), 30);
        assert!(report.failed() > 0, "no failures despite injection");
        assert!(report.completed() > 0, "everything failed");
        // Failed requests have no prediction.
        for c in &report.completions {
            assert_eq!(c.ok, c.prediction.is_some());
        }
    }

    #[test]
    fn pipelining_overlaps_stages() {
        // Two stages of ~2 ms per item, 24 items, batch 1: sequential
        // execution would need >= 96 ms; a pipeline should stay well
        // under 1.5x the single-stage total.
        let mut cfg = fast_cfg();
        cfg.batch.max_batch = 1;
        let inputs: Vec<Vec<f32>> = (0..24).map(|_| vec![0.0; 4]).collect();
        let report = run_pipeline(
            vec![sim_stage("a", 2000, 4), sim_stage("b", 2000, 4)],
            &cfg,
            inputs,
        );
        let wall = report.wall.as_secs_f64();
        assert!(wall < 0.096, "no pipeline overlap: wall {wall}");
    }

    #[test]
    fn link_simulation_adds_time() {
        let mut with_link = fast_cfg();
        with_link.simulate_link = true;
        with_link.link.base_latency_s = 3e-3;
        let inputs: Vec<Vec<f32>> = (0..8).map(|_| vec![0.0; 4]).collect();
        let spec = || vec![sim_stage("a", 10, 4), sim_stage("b", 10, 4)];
        let fast = run_pipeline(spec(), &fast_cfg(), inputs.clone());
        let slow = run_pipeline(spec(), &with_link, inputs);
        assert!(slow.wall > fast.wall);
        assert!(slow.stages[0].link > Duration::ZERO);
        assert_eq!(slow.stages[1].link, Duration::ZERO, "last stage has no link");
    }

    #[test]
    fn single_stage_pipeline_works() {
        let inputs: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32; 4]).collect();
        let report = run_pipeline(vec![sim_stage("only", 10, 4)], &fast_cfg(), inputs);
        assert_eq!(report.completed(), 5);
    }

    #[test]
    fn property_conservation_under_random_topologies() {
        property("pipeline conserves requests", 12, |rng| {
            let n_stages = Gen::usize_in(rng, 1..4);
            let n_req = Gen::usize_in(rng, 1..30);
            let stages: Vec<StageSpec> = (0..n_stages)
                .map(|s| sim_stage(&format!("s{s}"), Gen::usize_in(rng, 1..50) as u64, 4))
                .collect();
            let mut cfg = fast_cfg();
            cfg.batch.max_batch = Gen::usize_in(rng, 1..9);
            cfg.queue_depth = Gen::usize_in(rng, 1..6);
            let inputs: Vec<Vec<f32>> = (0..n_req).map(|_| vec![1.0; 4]).collect();
            let report = run_pipeline(stages, &cfg, inputs);
            assert_eq!(report.completions.len(), n_req);
            assert_eq!(report.completed(), n_req);
        });
    }
}
