//! Pipeline measurement: per-request latency, per-stage busy/link time,
//! end-to-end throughput — the quantities Definition 4 predicts and the
//! benches compare against the analytical model.

use crate::util::stats::{percentile, Summary};
use std::time::Duration;

/// One finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Request id (submission order).
    pub id: u64,
    /// End-to-end latency from submission to completion.
    pub latency: Duration,
    /// False when the request failed or was shed at a full queue.
    pub ok: bool,
    /// argmax of the final logits (classifier pipelines).
    pub prediction: Option<usize>,
}

/// Per-stage accounting filled in by the stage threads.
#[derive(Debug, Clone, Default)]
pub struct StageStats {
    /// Stage display name.
    pub name: String,
    /// Batches served.
    pub batches: u64,
    /// Items served across all batches.
    pub items: u64,
    /// Total compute occupancy.
    pub busy: Duration,
    /// Total link-transfer occupancy.
    pub link: Duration,
    /// Failed or dropped requests charged to this stage.
    pub failures: u64,
}

impl StageStats {
    /// Mean batch fill (items per batch; 0 when no batch ran).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.items as f64 / self.batches as f64
        }
    }
}

/// Full pipeline run report.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// Every request that left the pipeline.
    pub completions: Vec<Completion>,
    /// Wall-clock (or virtual-clock) span of the run.
    pub wall: Duration,
    /// Per-stage accounting, in pipeline order.
    pub stages: Vec<StageStats>,
}

impl PipelineReport {
    /// Number of successful completions.
    pub fn completed(&self) -> usize {
        self.completions.iter().filter(|c| c.ok).count()
    }

    /// Number of failed or dropped completions.
    pub fn failed(&self) -> usize {
        self.completions.len() - self.completed()
    }

    /// End-to-end throughput over the wall clock (inferences/s).
    /// Degenerate runs (nothing completed, or a zero-length wall clock)
    /// report 0 rather than NaN/inf — the simulator produces such
    /// reports for empty scenarios and fully-dropped workloads.
    pub fn throughput(&self) -> f64 {
        let wall = self.wall.as_secs_f64();
        if self.completed() == 0 || wall <= 0.0 {
            return 0.0;
        }
        self.completed() as f64 / wall
    }

    /// Latency summary over successful completions. Empty runs yield an
    /// empty [`Summary`] whose `mean()` is 0 (never NaN).
    pub fn latency_summary(&self) -> Summary {
        let mut s = Summary::new();
        for c in self.completions.iter().filter(|c| c.ok) {
            s.add(c.latency.as_secs_f64());
        }
        s
    }

    /// Latency percentile over successful completions; 0.0 when none
    /// completed (a defined floor beats propagating NaN into reports).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        let xs: Vec<f64> = self
            .completions
            .iter()
            .filter(|c| c.ok)
            .map(|c| c.latency.as_secs_f64())
            .collect();
        if xs.is_empty() {
            return 0.0;
        }
        percentile(&xs, p)
    }

    /// Pretty table for CLI/bench output.
    pub fn render(&self) -> String {
        use crate::util::units::{fmt_throughput, fmt_time_s};
        let lat = self.latency_summary();
        let mut out = String::new();
        out.push_str(&format!(
            "requests: {} ok, {} failed, wall {}\n",
            self.completed(),
            self.failed(),
            fmt_time_s(self.wall.as_secs_f64())
        ));
        out.push_str(&format!(
            "throughput: {}   latency mean {} p50 {} p99 {}\n",
            fmt_throughput(self.throughput()),
            fmt_time_s(lat.mean()),
            fmt_time_s(self.latency_percentile(50.0)),
            fmt_time_s(self.latency_percentile(99.0)),
        ));
        for s in &self.stages {
            out.push_str(&format!(
                "  stage {:<10} batches {:>5} (mean fill {:.2}) busy {} link {}\n",
                s.name,
                s.batches,
                s.mean_batch(),
                fmt_time_s(s.busy.as_secs_f64()),
                fmt_time_s(s.link.as_secs_f64()),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> PipelineReport {
        PipelineReport {
            completions: (0..10)
                .map(|i| Completion {
                    id: i,
                    latency: Duration::from_millis(10 + i),
                    ok: i != 3,
                    prediction: Some(i as usize % 10),
                })
                .collect(),
            wall: Duration::from_millis(100),
            stages: vec![StageStats {
                name: "A".into(),
                batches: 5,
                items: 10,
                busy: Duration::from_millis(60),
                link: Duration::from_millis(10),
                failures: 1,
            }],
        }
    }

    #[test]
    fn counts_and_throughput() {
        let r = report();
        assert_eq!(r.completed(), 9);
        assert_eq!(r.failed(), 1);
        assert!((r.throughput() - 90.0).abs() < 1e-9);
    }

    #[test]
    fn latency_stats_exclude_failures() {
        let r = report();
        assert_eq!(r.latency_summary().count(), 9);
        let p50 = r.latency_percentile(50.0);
        assert!(p50 >= 0.010 && p50 <= 0.019);
    }

    #[test]
    fn render_contains_key_fields() {
        let s = report().render();
        assert!(s.contains("9 ok"));
        assert!(s.contains("stage A"));
        assert!(s.contains("mean fill 2.00"));
    }

    #[test]
    fn empty_report_is_well_defined() {
        // A scenario with zero requests: no completions, zero wall.
        let r = PipelineReport {
            completions: Vec::new(),
            wall: Duration::ZERO,
            stages: vec![StageStats { name: "A".into(), ..Default::default() }],
        };
        assert_eq!(r.completed(), 0);
        assert_eq!(r.failed(), 0);
        assert_eq!(r.throughput(), 0.0);
        assert_eq!(r.latency_percentile(50.0), 0.0);
        assert_eq!(r.latency_percentile(99.0), 0.0);
        let lat = r.latency_summary();
        assert_eq!(lat.count(), 0);
        assert!(lat.mean() == 0.0, "empty mean must not be NaN");
        assert_eq!(r.stages[0].mean_batch(), 0.0);
        let text = r.render();
        assert!(!text.contains("NaN"), "render leaked NaN: {text}");
    }

    #[test]
    fn all_failed_report_is_well_defined() {
        // Every request dropped/failed: ok-filtered stats must stay
        // finite even though the wall clock is non-zero.
        let r = PipelineReport {
            completions: (0..5)
                .map(|i| Completion {
                    id: i,
                    latency: Duration::from_millis(1),
                    ok: false,
                    prediction: None,
                })
                .collect(),
            wall: Duration::from_millis(10),
            stages: Vec::new(),
        };
        assert_eq!(r.completed(), 0);
        assert_eq!(r.failed(), 5);
        assert_eq!(r.throughput(), 0.0);
        assert_eq!(r.latency_percentile(99.0), 0.0);
        assert!(r.latency_summary().mean() == 0.0);
        assert!(!r.render().contains("NaN"));
    }

    #[test]
    fn zero_wall_with_completions_is_finite() {
        // Instantaneous virtual runs must not divide by zero.
        let r = PipelineReport {
            completions: vec![Completion {
                id: 0,
                latency: Duration::ZERO,
                ok: true,
                prediction: None,
            }],
            wall: Duration::ZERO,
            stages: Vec::new(),
        };
        assert_eq!(r.throughput(), 0.0);
        assert!(r.throughput().is_finite());
    }
}
