//! # partir — automated DNN inference partitioning for distributed embedded systems
//!
//! A reproduction of Kreß et al. (2024): a hardware-aware design-space
//! exploration framework that finds Pareto-optimal partitioning points for
//! DNN inference over a chain of embedded accelerator platforms, plus a
//! runtime that executes the chosen partitioning as an asynchronous
//! pipeline via AOT-compiled XLA artifacts, and a deterministic
//! discrete-event simulator that serves millions of requests through any
//! explored deployment.
//!
//! ## The pipeline in five lines
//!
//! ```
//! use partir::{config::SystemConfig, explorer::ExploreRequest, zoo};
//! let model = zoo::tiny_cnn(10);                 // a layer DAG from the zoo
//! let mut sys = SystemConfig::paper_two_platform();
//! sys.search.victory = 5; sys.search.max_samples = 50; // quick mapper budget
//! let ex = ExploreRequest::chain().run(&model, &sys);
//! assert!(ex.favorite.is_some() && !ex.pareto.is_empty());
//! ```
//!
//! ## Partitioning models
//!
//! Every exploration is described by an [`explorer::ExploreRequest`]
//! (mode, models, shared cache, worker budget, replication) and executed
//! by [`explorer::Explorer::run`].
//!
//! * **Chain cuts** (the paper's Definition 1): cut positions on one
//!   topological schedule — [`explorer::ExploreMode::Chain`], exhaustive
//!   on unreplicated two-platform systems, NSGA-II beyond.
//! * **Convex DAG partitions** (beyond the paper): monotone
//!   layer→platform assignments whose stages may run parallel branches
//!   on distinct platforms — [`explorer::ExploreMode::Dag`], built on
//!   [`graph::partition::DagPartition`] and evaluated by
//!   [`explorer::PlanEvaluator::evaluate_dag`]. On sequential models
//!   this collapses bit-identically onto the chain result.
//! * **Per-stage replication** (cluster scale): a
//!   [`config::ReplicationCfg`] node inventory — from
//!   [`config::SystemConfig::cluster`], a `[replication]` TOML section
//!   or [`explorer::ExploreRequest::replication`] — adds one
//!   replica-count gene per platform slot; stage throughput scales with
//!   the count while memory stays per node and energy adds per node.
//!
//! ## Architecture (three layers)
//!
//! * **L3 — this crate**: graph analysis ([`graph`]), memory/link/
//!   accuracy/hardware models ([`memory`], [`link`], [`accuracy`],
//!   [`hw`]), NSGA-II ([`nsga2`]), the explorers ([`explorer`]), the
//!   wall-clock pipeline coordinator ([`coordinator`]), the
//!   discrete-event serving simulator ([`sim`]), and the deterministic
//!   observability layer ([`obs`]: spans, metrics, Perfetto export).
//! * **L2 — `python/compile/model.py`**: JAX model (build time only).
//! * **L1 — `python/compile/kernels/`**: Pallas kernels (build time only).
//!
//! See `README.md` for the 60-second CLI quickstart and `DESIGN.md` for
//! the full system inventory and the per-experiment index mapping every
//! paper table/figure to a bench target.

#![warn(missing_docs)]

pub mod accuracy;
pub mod config;
pub mod coordinator;
pub mod explorer;
pub mod graph;
pub mod hw;
pub mod link;
pub mod memory;
pub mod nsga2;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod util;
pub mod zoo;
