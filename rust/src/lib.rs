//! # partir — automated DNN inference partitioning for distributed embedded systems
//!
//! A reproduction of Kreß et al. (2024): a hardware-aware design-space
//! exploration framework that finds Pareto-optimal partitioning points for
//! DNN inference over a chain of embedded accelerator platforms, plus a
//! runtime that executes the chosen partitioning as an asynchronous
//! pipeline via AOT-compiled XLA artifacts.
//!
//! Architecture (three layers):
//! * **L3 — this crate**: graph analysis, memory/link/accuracy/hardware
//!   models, NSGA-II, the explorer, the pipeline coordinator, and the
//!   discrete-event serving simulator (`sim`).
//! * **L2 — `python/compile/model.py`**: JAX model (build time only).
//! * **L1 — `python/compile/kernels/`**: Pallas kernels (build time only).
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every paper table/figure to a bench target.

pub mod accuracy;
pub mod config;
pub mod explorer;
pub mod graph;
pub mod hw;
pub mod coordinator;
pub mod nsga2;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod link;
pub mod memory;
pub mod zoo;
pub mod testkit;
pub mod util;
