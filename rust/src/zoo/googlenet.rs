//! GoogLeNet (Szegedy et al. 2015), torchvision `googlenet` layout with
//! batch norm, no auxiliary classifiers, and the well-known torchvision
//! quirk that the "5×5" inception branch actually uses a 3×3 kernel.
//! Published parameter count: 6,624,904.

use super::common::{classifier, conv_bn_act, maxpool};
use crate::graph::{Act, Graph, LayerKind, NodeId, Pool2d};

struct InceptionCfg {
    ch1x1: usize,
    ch3x3red: usize,
    ch3x3: usize,
    ch5x5red: usize,
    ch5x5: usize,
    pool_proj: usize,
}

fn inception(g: &mut Graph, inp: NodeId, cfg: &InceptionCfg) -> NodeId {
    let b1 = conv_bn_act(g, inp, cfg.ch1x1, 1, 1, 0, Act::Relu);
    let b2a = conv_bn_act(g, inp, cfg.ch3x3red, 1, 1, 0, Act::Relu);
    let b2 = conv_bn_act(g, b2a, cfg.ch3x3, 3, 1, 1, Act::Relu);
    let b3a = conv_bn_act(g, inp, cfg.ch5x5red, 1, 1, 0, Act::Relu);
    // torchvision uses kernel 3 (padding 1) here despite the name.
    let b3 = conv_bn_act(g, b3a, cfg.ch5x5, 3, 1, 1, Act::Relu);
    let pool = g.add(
        LayerKind::MaxPool(Pool2d { kernel: 3, stride: 1, pad: 1, ceil: true }),
        &[inp],
    );
    let b4 = conv_bn_act(g, pool, cfg.pool_proj, 1, 1, 0, Act::Relu);
    g.add(LayerKind::Concat, &[b1, b2, b3, b4])
}

const CFGS: &[InceptionCfg] = &[
    // 3a, 3b
    InceptionCfg { ch1x1: 64, ch3x3red: 96, ch3x3: 128, ch5x5red: 16, ch5x5: 32, pool_proj: 32 },
    InceptionCfg { ch1x1: 128, ch3x3red: 128, ch3x3: 192, ch5x5red: 32, ch5x5: 96, pool_proj: 64 },
    // 4a..4e
    InceptionCfg { ch1x1: 192, ch3x3red: 96, ch3x3: 208, ch5x5red: 16, ch5x5: 48, pool_proj: 64 },
    InceptionCfg { ch1x1: 160, ch3x3red: 112, ch3x3: 224, ch5x5red: 24, ch5x5: 64, pool_proj: 64 },
    InceptionCfg { ch1x1: 128, ch3x3red: 128, ch3x3: 256, ch5x5red: 24, ch5x5: 64, pool_proj: 64 },
    InceptionCfg { ch1x1: 112, ch3x3red: 144, ch3x3: 288, ch5x5red: 32, ch5x5: 64, pool_proj: 64 },
    InceptionCfg { ch1x1: 256, ch3x3red: 160, ch3x3: 320, ch5x5red: 32, ch5x5: 128, pool_proj: 128 },
    // 5a, 5b
    InceptionCfg { ch1x1: 256, ch3x3red: 160, ch3x3: 320, ch5x5red: 32, ch5x5: 128, pool_proj: 128 },
    InceptionCfg { ch1x1: 384, ch3x3red: 192, ch3x3: 384, ch5x5red: 48, ch5x5: 128, pool_proj: 128 },
];

/// torchvision `googlenet` (6,624,904 parameters).
pub fn googlenet(classes: usize) -> Graph {
    let mut g = Graph::new("googlenet");
    let x = g.input(3, 224, 224);
    let c1 = conv_bn_act(&mut g, x, 64, 7, 2, 3, Act::Relu); // -> 112
    let p1 = maxpool(&mut g, c1, 3, 2, 0, true); // -> 56
    let c2 = conv_bn_act(&mut g, p1, 64, 1, 1, 0, Act::Relu);
    let c3 = conv_bn_act(&mut g, c2, 192, 3, 1, 1, Act::Relu);
    let p2 = maxpool(&mut g, c3, 3, 2, 0, true); // -> 28
    let i3a = inception(&mut g, p2, &CFGS[0]);
    let i3b = inception(&mut g, i3a, &CFGS[1]);
    let p3 = maxpool(&mut g, i3b, 3, 2, 0, true); // -> 14
    let mut x4 = p3;
    for cfg in &CFGS[2..7] {
        x4 = inception(&mut g, x4, cfg);
    }
    let p4 = maxpool(&mut g, x4, 2, 2, 0, true); // -> 7
    let i5a = inception(&mut g, p4, &CFGS[7]);
    let i5b = inception(&mut g, i5a, &CFGS[8]);
    classifier(&mut g, i5b, classes, true);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;

    #[test]
    fn param_count_matches_torchvision() {
        let g = googlenet(1000);
        g.validate().unwrap();
        assert_eq!(g.total_params(), 6_624_904);
    }

    #[test]
    fn mac_count_close_to_published() {
        // ~1.5 GMACs at 224x224.
        let g = googlenet(1000);
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((1.35..1.65).contains(&gmacs), "GoogLeNet GMACs {gmacs}");
    }

    #[test]
    fn inception_output_channels() {
        let g = googlenet(1000);
        // 3a: 64+128+32+32 = 256 at 28x28.
        assert_eq!(g.by_name("Concat_0").unwrap().out_shape, Shape::chw(256, 28, 28));
        // 5b: 384+384+128+128 = 1024 at 7x7.
        assert_eq!(g.by_name("Concat_8").unwrap().out_shape, Shape::chw(1024, 7, 7));
    }

    #[test]
    fn nine_inception_modules() {
        let g = googlenet(1000);
        let concats = g.nodes.iter().filter(|n| matches!(n.kind, LayerKind::Concat)).count();
        assert_eq!(concats, 9);
    }
}
