//! Shared building blocks for the zoo's graph constructors.

use crate::graph::{Act, Graph, LayerKind, NodeId, Pool2d};

/// Plain convolution (optionally biased).
pub fn conv(
    g: &mut Graph,
    inp: NodeId,
    out_c: usize,
    k: usize,
    s: usize,
    p: usize,
    bias: bool,
) -> NodeId {
    g.add(
        LayerKind::Conv2d {
            out_c,
            kernel: (k, k),
            stride: (s, s),
            pad: (p, p),
            groups: 1,
            bias,
        },
        &[inp],
    )
}

/// Grouped convolution (RegNet) / depthwise when `groups == in_c`.
pub fn gconv(
    g: &mut Graph,
    inp: NodeId,
    out_c: usize,
    k: usize,
    s: usize,
    p: usize,
    groups: usize,
) -> NodeId {
    g.add(
        LayerKind::Conv2d {
            out_c,
            kernel: (k, k),
            stride: (s, s),
            pad: (p, p),
            groups,
            bias: false,
        },
        &[inp],
    )
}

/// conv → BN (no activation), the torchvision `BasicConv2d`-minus-ReLU.
pub fn conv_bn(g: &mut Graph, inp: NodeId, out_c: usize, k: usize, s: usize, p: usize) -> NodeId {
    let c = conv(g, inp, out_c, k, s, p, false);
    g.add(LayerKind::BatchNorm, &[c])
}

/// conv → BN → activation.
pub fn conv_bn_act(
    g: &mut Graph,
    inp: NodeId,
    out_c: usize,
    k: usize,
    s: usize,
    p: usize,
    act: Act,
) -> NodeId {
    let b = conv_bn(g, inp, out_c, k, s, p);
    g.add(LayerKind::Activation(act), &[b])
}

/// Grouped conv → BN → activation.
pub fn gconv_bn_act(
    g: &mut Graph,
    inp: NodeId,
    out_c: usize,
    k: usize,
    s: usize,
    p: usize,
    groups: usize,
    act: Act,
) -> NodeId {
    let c = gconv(g, inp, out_c, k, s, p, groups);
    let b = g.add(LayerKind::BatchNorm, &[c]);
    g.add(LayerKind::Activation(act), &[b])
}

/// Append a ReLU.
pub fn relu(g: &mut Graph, inp: NodeId) -> NodeId {
    g.add(LayerKind::Activation(Act::Relu), &[inp])
}

/// Append a 2-D max pool.
pub fn maxpool(g: &mut Graph, inp: NodeId, k: usize, s: usize, p: usize, ceil: bool) -> NodeId {
    g.add(LayerKind::MaxPool(Pool2d { kernel: k, stride: s, pad: p, ceil }), &[inp])
}

/// Append a global average pool.
pub fn gap(g: &mut Graph, inp: NodeId) -> NodeId {
    g.add(LayerKind::GlobalAvgPool, &[inp])
}

/// GAP → Flatten → (Dropout) → Linear classifier tail.
pub fn classifier(
    g: &mut Graph,
    inp: NodeId,
    classes: usize,
    dropout: bool,
) -> NodeId {
    let p = gap(g, inp);
    let f = g.add(LayerKind::Flatten, &[p]);
    let f = if dropout { g.add(LayerKind::Dropout, &[f]) } else { f };
    g.add(LayerKind::Linear { out_features: classes, bias: true }, &[f])
}

/// Squeeze-and-excitation gate on `inp` (torchvision layout):
/// GAP → conv1x1(se_c, bias) → act → conv1x1(c, bias) → Sigmoid → Mul.
pub fn squeeze_excite(
    g: &mut Graph,
    inp: NodeId,
    se_c: usize,
    act: Act,
) -> NodeId {
    let c = g.node(inp).out_shape.channels();
    let pooled = gap(g, inp);
    let fc1 = conv(g, pooled, se_c, 1, 1, 0, true);
    let a = g.add(LayerKind::Activation(act), &[fc1]);
    let fc2 = conv(g, a, c, 1, 1, 0, true);
    let gate = g.add(LayerKind::Activation(Act::Sigmoid), &[fc2]);
    g.add(LayerKind::Mul, &[inp, gate])
}
