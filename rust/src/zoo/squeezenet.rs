//! SqueezeNet v1.1 (Iandola et al. 2016), torchvision `squeezenet1_1`:
//! biased convs, ceil-mode maxpools, conv classifier.
//! Published parameter count: 1,235,496.

use super::common::{conv, gap, maxpool, relu};
use crate::graph::{Graph, LayerKind, NodeId};

/// Fire module: squeeze 1×1 → (expand 1×1 ∥ expand 3×3) → concat.
fn fire(g: &mut Graph, inp: NodeId, squeeze_c: usize, expand_c: usize) -> NodeId {
    let s = conv(g, inp, squeeze_c, 1, 1, 0, true);
    let s = relu(g, s);
    let e1 = conv(g, s, expand_c, 1, 1, 0, true);
    let e1 = relu(g, e1);
    let e3 = conv(g, s, expand_c, 3, 1, 1, true);
    let e3 = relu(g, e3);
    g.add(LayerKind::Concat, &[e1, e3])
}

/// torchvision `squeezenet1_1` (1,235,496 parameters).
pub fn squeezenet1_1(classes: usize) -> Graph {
    let mut g = Graph::new("squeezenet1_1");
    let x = g.input(3, 224, 224);
    let c1 = conv(&mut g, x, 64, 3, 2, 0, true); // 224 -> 111
    let r1 = relu(&mut g, c1);
    let p1 = maxpool(&mut g, r1, 3, 2, 0, true); // -> 55
    let f2 = fire(&mut g, p1, 16, 64);
    let f3 = fire(&mut g, f2, 16, 64);
    let p2 = maxpool(&mut g, f3, 3, 2, 0, true); // -> 27
    let f4 = fire(&mut g, p2, 32, 128);
    let f5 = fire(&mut g, f4, 32, 128);
    let p3 = maxpool(&mut g, f5, 3, 2, 0, true); // -> 13
    let f6 = fire(&mut g, p3, 48, 192);
    let f7 = fire(&mut g, f6, 48, 192);
    let f8 = fire(&mut g, f7, 64, 256);
    let f9 = fire(&mut g, f8, 64, 256);
    // Classifier: dropout → conv1x1 → relu → GAP.
    let d = g.add(LayerKind::Dropout, &[f9]);
    let cc = conv(&mut g, d, classes, 1, 1, 0, true);
    let rc = relu(&mut g, cc);
    let p = gap(&mut g, rc);
    g.add(LayerKind::Flatten, &[p]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;

    #[test]
    fn param_count_matches_torchvision() {
        let g = squeezenet1_1(1000);
        g.validate().unwrap();
        assert_eq!(g.total_params(), 1_235_496);
    }

    #[test]
    fn mac_count_close_to_published() {
        // ~0.35 GMACs at 224x224.
        let g = squeezenet1_1(1000);
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((gmacs - 0.35).abs() < 0.05, "SqueezeNet GMACs {gmacs}");
    }

    #[test]
    fn spatial_schedule() {
        let g = squeezenet1_1(1000);
        // Stem conv: 224 -> 111; final fire output: 512 x 13 x 13.
        assert_eq!(g.by_name("Conv_0").unwrap().out_shape, Shape::chw(64, 111, 111));
        let last_fire = g.by_name("Concat_7").unwrap();
        assert_eq!(last_fire.out_shape, Shape::chw(512, 13, 13));
    }

    #[test]
    fn paper_partition_point_exists() {
        // Fig 2(d) picks "ReLu_2" — the first fire module's squeeze relu.
        let g = squeezenet1_1(1000);
        assert!(g.by_name("Relu_2").is_some());
    }

    #[test]
    fn eight_fire_modules() {
        let g = squeezenet1_1(1000);
        let concats = g.nodes.iter().filter(|n| matches!(n.kind, LayerKind::Concat)).count();
        assert_eq!(concats, 8);
    }
}
