//! ResNet-50 (He et al. 2016), torchvision `resnet50`: bottleneck blocks,
//! unbiased convs + BN, stride on the 3×3 (v1.5 variant).
//! Published parameter count: 25,557,032.

use super::common::{conv_bn, conv_bn_act, maxpool, relu};
use crate::graph::{Act, Graph, LayerKind, NodeId};

/// Bottleneck: 1×1 reduce → 3×3 (stride here, v1.5) → 1×1 expand ×4,
/// residual add, ReLU. `downsample` projects the identity when shape or
/// stride changes.
fn bottleneck(
    g: &mut Graph,
    inp: NodeId,
    width: usize,
    stride: usize,
    downsample: bool,
) -> NodeId {
    let out_c = width * 4;
    let a = conv_bn_act(g, inp, width, 1, 1, 0, Act::Relu);
    let b = conv_bn_act(g, a, width, 3, stride, 1, Act::Relu);
    let c = conv_bn(g, b, out_c, 1, 1, 0);
    let identity = if downsample {
        conv_bn(g, inp, out_c, 1, stride, 0)
    } else {
        inp
    };
    let sum = g.add(LayerKind::Add, &[identity, c]);
    relu(g, sum)
}

fn stage(g: &mut Graph, mut x: NodeId, width: usize, blocks: usize, stride: usize) -> NodeId {
    x = bottleneck(g, x, width, stride, true);
    for _ in 1..blocks {
        x = bottleneck(g, x, width, 1, false);
    }
    x
}

/// torchvision `resnet50` (25,557,032 parameters).
pub fn resnet50(classes: usize) -> Graph {
    let mut g = Graph::new("resnet50");
    let x = g.input(3, 224, 224);
    let stem = conv_bn_act(&mut g, x, 64, 7, 2, 3, Act::Relu); // -> 112
    let p = maxpool(&mut g, stem, 3, 2, 1, false); // -> 56
    let s1 = stage(&mut g, p, 64, 3, 1); // 256 x 56
    let s2 = stage(&mut g, s1, 128, 4, 2); // 512 x 28
    let s3 = stage(&mut g, s2, 256, 6, 2); // 1024 x 14
    let s4 = stage(&mut g, s3, 512, 3, 2); // 2048 x 7
    super::common::classifier(&mut g, s4, classes, false);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;

    #[test]
    fn param_count_matches_torchvision() {
        let g = resnet50(1000);
        g.validate().unwrap();
        assert_eq!(g.total_params(), 25_557_032);
    }

    #[test]
    fn mac_count_close_to_published() {
        // ~4.09 GMACs at 224x224 (v1.5 stride placement: 4.11).
        let g = resnet50(1000);
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((3.95..4.25).contains(&gmacs), "ResNet-50 GMACs {gmacs}");
    }

    #[test]
    fn stage_shapes() {
        let g = resnet50(1000);
        let gap_node = g.by_name("GlobalAvgPool_0").unwrap();
        let pre = g.node(gap_node.inputs[0]);
        assert_eq!(pre.out_shape, Shape::chw(2048, 7, 7));
    }

    #[test]
    fn relu_count_and_paper_point() {
        let g = resnet50(1000);
        let relus = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::Activation(Act::Relu)))
            .count();
        // stem + 16 blocks x 3 = 49.
        assert_eq!(relus, 49);
        // Fig 2(b) picks "ReLu_11" as the max-throughput point.
        assert!(g.by_name("Relu_11").is_some());
    }

    #[test]
    fn conv_count() {
        let g = resnet50(1000);
        let convs = g.nodes.iter().filter(|n| matches!(n.kind, LayerKind::Conv2d { .. })).count();
        // 1 stem + 16 blocks x 3 + 4 downsamples = 53.
        assert_eq!(convs, 53);
    }
}
