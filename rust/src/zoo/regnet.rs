//! RegNetX-400MF (Radosavovic et al. 2020), torchvision `regnet_x_400mf`:
//! depths [1, 2, 7, 12], widths [32, 64, 160, 400], group width 16,
//! simple stem of width 32. X-blocks (no SE), ReLU + BN everywhere.
//! Published parameter count: 5,495,976.

use super::common::{classifier, conv_bn, conv_bn_act, gconv_bn_act, relu};
use crate::graph::{Act, Graph, LayerKind, NodeId};

const DEPTHS: [usize; 4] = [1, 2, 7, 12];
const WIDTHS: [usize; 4] = [32, 64, 160, 400];
const GROUP_WIDTH: usize = 16;

/// RegNet X block: 1×1 → 3×3 grouped (stride) → 1×1, residual, ReLU.
/// Bottleneck ratio is 1.0 for RegNetX, so the inner width equals w_out.
fn x_block(g: &mut Graph, inp: NodeId, w_out: usize, stride: usize) -> NodeId {
    let w_in = g.node(inp).out_shape.channels();
    let groups = w_out / GROUP_WIDTH;
    let a = conv_bn_act(g, inp, w_out, 1, 1, 0, Act::Relu);
    let b = gconv_bn_act(g, a, w_out, 3, stride, 1, groups, Act::Relu);
    let c = conv_bn(g, b, w_out, 1, 1, 0);
    let identity = if stride != 1 || w_in != w_out {
        conv_bn(g, inp, w_out, 1, stride, 0)
    } else {
        inp
    };
    let sum = g.add(LayerKind::Add, &[identity, c]);
    relu(g, sum)
}

/// torchvision `regnet_x_400mf` (5,495,976 parameters).
pub fn regnet_x_400mf(classes: usize) -> Graph {
    let mut g = Graph::new("regnet_x_400mf");
    let x = g.input(3, 224, 224);
    // Stem: 3x3/2 width 32.
    let mut cur = conv_bn_act(&mut g, x, 32, 3, 2, 1, Act::Relu); // -> 112
    for (d, w) in DEPTHS.iter().zip(WIDTHS.iter()) {
        cur = x_block(&mut g, cur, *w, 2);
        for _ in 1..*d {
            cur = x_block(&mut g, cur, *w, 1);
        }
    }
    classifier(&mut g, cur, classes, false);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;

    #[test]
    fn param_count_matches_torchvision() {
        let g = regnet_x_400mf(1000);
        g.validate().unwrap();
        assert_eq!(g.total_params(), 5_495_976);
    }

    #[test]
    fn mac_count_close_to_published() {
        // ~0.41 GMACs at 224x224.
        let g = regnet_x_400mf(1000);
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((0.35..0.48).contains(&gmacs), "RegNetX-400MF GMACs {gmacs}");
    }

    #[test]
    fn final_width_400_at_7x7() {
        let g = regnet_x_400mf(1000);
        let gap_node = g.by_name("GlobalAvgPool_0").unwrap();
        let pre = g.node(gap_node.inputs[0]);
        assert_eq!(pre.out_shape, Shape::chw(400, 7, 7));
    }

    #[test]
    fn block_count() {
        let g = regnet_x_400mf(1000);
        let adds = g.nodes.iter().filter(|n| matches!(n.kind, LayerKind::Add)).count();
        assert_eq!(adds, DEPTHS.iter().sum::<usize>());
    }
}
