//! The executable tiny CNN — the model the AOT artifacts implement.
//!
//! Its topology mirrors `python/compile/model.py` layer-for-layer so that
//! the explorer's partition decisions map one-to-one onto the exported
//! HLO segment artifacts. 3×32×32 input, three conv-relu-pool blocks,
//! linear classifier — ~100 K parameters, small enough to AOT-compile and
//! serve on the CPU PJRT client in seconds.

use super::common::{conv, maxpool, relu};
use crate::graph::{Graph, LayerKind};

/// Channel plan shared with the python model.
pub const TINY_CHANNELS: [usize; 3] = [16, 32, 64];
/// Input shape `(c, h, w)` shared with the python model.
pub const TINY_INPUT: (usize, usize, usize) = (3, 32, 32);
/// Classifier classes of the executable model.
pub const TINY_CLASSES: usize = 10;

/// The executable tiny CNN (3 conv blocks + linear classifier).
pub fn tiny_cnn(classes: usize) -> Graph {
    let mut g = Graph::new("tiny_cnn");
    let (c, h, w) = TINY_INPUT;
    let mut x = g.input(c, h, w);
    for &width in &TINY_CHANNELS {
        x = conv(&mut g, x, width, 3, 1, 1, true);
        x = relu(&mut g, x);
        x = maxpool(&mut g, x, 2, 2, 0, false);
    }
    let f = g.add(LayerKind::Flatten, &[x]);
    g.add(LayerKind::Linear { out_features: classes, bias: true }, &[f]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;

    #[test]
    fn structure() {
        let g = tiny_cnn(TINY_CLASSES);
        g.validate().unwrap();
        // 3 conv blocks + flatten + fc.
        let flat = g.by_name("Flatten_0").unwrap();
        let pre = g.node(flat.inputs[0]);
        assert_eq!(pre.out_shape, Shape::chw(64, 4, 4));
    }

    #[test]
    fn params_match_python_model() {
        let g = tiny_cnn(TINY_CLASSES);
        // conv1: 16*3*9+16 = 448; conv2: 32*16*9+32 = 4640;
        // conv3: 64*32*9+64 = 18496; fc: 1024*10+10 = 10250.
        assert_eq!(g.total_params(), 448 + 4640 + 18496 + 10250);
    }

    #[test]
    fn partitionable_between_blocks() {
        let g = tiny_cnn(TINY_CLASSES);
        let order = crate::graph::topo::topo_sort(&g, crate::graph::topo::TieBreak::Deterministic);
        let cuts = crate::graph::partition::clean_cuts(&g, &order);
        // Chain topology: every inter-layer position is a clean cut.
        assert_eq!(cuts.len(), g.len() - 1);
    }
}
