//! VGG-16 (Simonyan & Zisserman 2015), torchvision `vgg16` layout:
//! biased 3×3 convolutions, no batch norm, three-layer classifier.
//! Published parameter count: 138,357,544.

use super::common::{conv, maxpool, relu};
use crate::graph::{Graph, LayerKind};

/// Configuration "D": channel widths per block, `M` = maxpool.
const CFG_D: &[&[usize]] = &[&[64, 64], &[128, 128], &[256, 256, 256], &[512, 512, 512], &[512, 512, 512]];

/// torchvision `vgg16` (138,357,544 parameters).
pub fn vgg16(classes: usize) -> Graph {
    let mut g = Graph::new("vgg16");
    let mut x = g.input(3, 224, 224);
    for block in CFG_D {
        for &width in *block {
            x = conv(&mut g, x, width, 3, 1, 1, true);
            x = relu(&mut g, x);
        }
        x = maxpool(&mut g, x, 2, 2, 0, false);
    }
    // torchvision inserts AdaptiveAvgPool2d(7) which is identity at 7x7.
    let f = g.add(LayerKind::Flatten, &[x]);
    let fc1 = g.add(LayerKind::Linear { out_features: 4096, bias: true }, &[f]);
    let r1 = relu(&mut g, fc1);
    let d1 = g.add(LayerKind::Dropout, &[r1]);
    let fc2 = g.add(LayerKind::Linear { out_features: 4096, bias: true }, &[d1]);
    let r2 = relu(&mut g, fc2);
    let d2 = g.add(LayerKind::Dropout, &[r2]);
    g.add(LayerKind::Linear { out_features: classes, bias: true }, &[d2]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;

    #[test]
    fn param_count_matches_torchvision() {
        let g = vgg16(1000);
        g.validate().unwrap();
        assert_eq!(g.total_params(), 138_357_544);
    }

    #[test]
    fn mac_count_close_to_published() {
        // ~15.47 GMACs for 224x224 inference.
        let g = vgg16(1000);
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((gmacs - 15.47).abs() < 0.1, "VGG-16 GMACs {gmacs}");
    }

    #[test]
    fn final_feature_map_is_7x7x512() {
        let g = vgg16(1000);
        let flat = g.by_name("Flatten_0").unwrap();
        let pre = g.node(flat.inputs[0]);
        assert_eq!(pre.out_shape, Shape::chw(512, 7, 7));
    }

    #[test]
    fn has_thirteen_convs_and_three_gemms() {
        let g = vgg16(1000);
        let convs = g.nodes.iter().filter(|n| matches!(n.kind, LayerKind::Conv2d { .. })).count();
        let gemms = g.nodes.iter().filter(|n| matches!(n.kind, LayerKind::Linear { .. })).count();
        assert_eq!(convs, 13);
        assert_eq!(gemms, 3);
        // Paper labels early partition points "ReLu 1"/"ReLu 2": they exist.
        assert!(g.by_name("Relu_1").is_some());
        assert!(g.by_name("Relu_2").is_some());
    }
}
