//! EfficientNet-B0 (Tan & Le 2019), torchvision `efficientnet_b0`:
//! MBConv blocks with squeeze-and-excitation, SiLU activations, BN,
//! 1280-wide head. Published parameter count: 5,288,548.

use super::common::{classifier, conv_bn, conv_bn_act, squeeze_excite};
use crate::graph::{Act, Graph, LayerKind, NodeId};

struct StageCfg {
    expand: usize,
    kernel: usize,
    stride: usize,
    out_c: usize,
    layers: usize,
}

const STAGES: &[StageCfg] = &[
    StageCfg { expand: 1, kernel: 3, stride: 1, out_c: 16, layers: 1 },
    StageCfg { expand: 6, kernel: 3, stride: 2, out_c: 24, layers: 2 },
    StageCfg { expand: 6, kernel: 5, stride: 2, out_c: 40, layers: 2 },
    StageCfg { expand: 6, kernel: 3, stride: 2, out_c: 80, layers: 3 },
    StageCfg { expand: 6, kernel: 5, stride: 1, out_c: 112, layers: 3 },
    StageCfg { expand: 6, kernel: 5, stride: 2, out_c: 192, layers: 4 },
    StageCfg { expand: 6, kernel: 3, stride: 1, out_c: 320, layers: 1 },
];

/// MBConv: expand 1×1 (skipped when ratio 1) → depthwise k×k → SE →
/// project 1×1, residual when stride 1 and channels match.
/// SE squeeze width is `in_c / 4` (relative to the block *input*,
/// torchvision convention).
fn mbconv(
    g: &mut Graph,
    inp: NodeId,
    expand: usize,
    kernel: usize,
    stride: usize,
    out_c: usize,
) -> NodeId {
    let in_c = g.node(inp).out_shape.channels();
    let exp_c = in_c * expand;
    let mut x = inp;
    if expand != 1 {
        x = conv_bn_act(g, x, exp_c, 1, 1, 0, Act::Silu);
    }
    // Depthwise.
    let pad = (kernel - 1) / 2;
    let dw = g.add(
        LayerKind::Conv2d {
            out_c: exp_c,
            kernel: (kernel, kernel),
            stride: (stride, stride),
            pad: (pad, pad),
            groups: exp_c,
            bias: false,
        },
        &[x],
    );
    let bn = g.add(LayerKind::BatchNorm, &[dw]);
    let act = g.add(LayerKind::Activation(Act::Silu), &[bn]);
    let se = squeeze_excite(g, act, (in_c / 4).max(1), Act::Silu);
    let proj = conv_bn(g, se, out_c, 1, 1, 0);
    if stride == 1 && in_c == out_c {
        g.add(LayerKind::Add, &[inp, proj])
    } else {
        proj
    }
}

/// torchvision `efficientnet_b0` (5,288,548 parameters).
pub fn efficientnet_b0(classes: usize) -> Graph {
    let mut g = Graph::new("efficientnet_b0");
    let x = g.input(3, 224, 224);
    let mut cur = conv_bn_act(&mut g, x, 32, 3, 2, 1, Act::Silu); // -> 112
    for s in STAGES {
        cur = mbconv(&mut g, cur, s.expand, s.kernel, s.stride, s.out_c);
        for _ in 1..s.layers {
            cur = mbconv(&mut g, cur, s.expand, s.kernel, 1, s.out_c);
        }
    }
    let head = conv_bn_act(&mut g, cur, 1280, 1, 1, 0, Act::Silu);
    classifier(&mut g, head, classes, true);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;

    #[test]
    fn param_count_matches_torchvision() {
        let g = efficientnet_b0(1000);
        g.validate().unwrap();
        assert_eq!(g.total_params(), 5_288_548);
    }

    #[test]
    fn mac_count_close_to_published() {
        // ~0.39 GMACs at 224x224.
        let g = efficientnet_b0(1000);
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((0.36..0.43).contains(&gmacs), "EfficientNet-B0 GMACs {gmacs}");
    }

    #[test]
    fn conv_count_covers_paper_points() {
        // Paper cites partition points Conv_45, Conv_56, Conv_79: the
        // graph must have at least 80 convolutions.
        let g = efficientnet_b0(1000);
        let convs = g.nodes.iter().filter(|n| matches!(n.kind, LayerKind::Conv2d { .. })).count();
        assert!(convs >= 80, "only {convs} convs");
        assert!(g.by_name("Conv_45").is_some());
        assert!(g.by_name("Conv_56").is_some());
        assert!(g.by_name("Conv_79").is_some());
    }

    #[test]
    fn head_shape() {
        let g = efficientnet_b0(1000);
        let gap_node = g.by_name("GlobalAvgPool_16").unwrap(); // 16 SE gaps before it
        let pre = g.node(gap_node.inputs[0]);
        assert_eq!(pre.out_shape, Shape::chw(1280, 7, 7));
    }

    #[test]
    fn sixteen_mbconv_blocks() {
        let g = efficientnet_b0(1000);
        // Each MBConv has exactly one SE gate (one Mul).
        let muls = g.nodes.iter().filter(|n| matches!(n.kind, LayerKind::Mul)).count();
        assert_eq!(muls, 16);
    }
}
