//! Model zoo: programmatic graph constructors for the six CNNs the paper
//! evaluates (§V-A) plus the executable tiny CNN.
//!
//! The paper ingests ONNX files exported from torchvision; we construct
//! structurally identical layer DAGs directly (same operator sequence,
//! shapes, parameter and MAC counts — validated against the published
//! totals in each module's tests). The DSE only consumes this structural
//! information, never the weights.

pub mod common;
pub mod efficientnet;
pub mod googlenet;
pub mod regnet;
pub mod resnet;
pub mod squeezenet;
pub mod tiny;
pub mod vgg;

use crate::graph::Graph;

pub use efficientnet::efficientnet_b0;
pub use googlenet::googlenet;
pub use regnet::regnet_x_400mf;
pub use resnet::resnet50;
pub use squeezenet::squeezenet1_1;
pub use tiny::tiny_cnn;
pub use vgg::vgg16;

/// Names of the six paper workloads, in the order Table II lists them.
pub const PAPER_MODELS: [&str; 6] = [
    "squeezenet1_1",
    "vgg16",
    "googlenet",
    "resnet50",
    "regnet_x_400mf",
    "efficientnet_b0",
];

/// Build a zoo model by name (1000 ImageNet classes for the paper models,
/// 10 classes for the executable tiny CNN).
///
/// ```
/// let g = partir::zoo::build("googlenet").unwrap();
/// g.validate().unwrap();
/// assert_eq!(g.total_params(), 6_624_904); // torchvision's published count
/// assert!(partir::zoo::build("alexnet").is_none());
/// ```
pub fn build(name: &str) -> Option<Graph> {
    match name {
        "vgg16" => Some(vgg16(1000)),
        "resnet50" => Some(resnet50(1000)),
        "googlenet" => Some(googlenet(1000)),
        "squeezenet1_1" => Some(squeezenet1_1(1000)),
        "regnet_x_400mf" => Some(regnet_x_400mf(1000)),
        "efficientnet_b0" => Some(efficientnet_b0(1000)),
        "tiny_cnn" => Some(tiny_cnn(tiny::TINY_CLASSES)),
        _ => None,
    }
}

/// All model names `build` accepts.
pub fn names() -> Vec<&'static str> {
    let mut v = PAPER_MODELS.to_vec();
    v.push("tiny_cnn");
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_accepts_all_names() {
        for name in names() {
            let g = build(name).unwrap_or_else(|| panic!("{name} missing"));
            g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(g.name, name);
        }
        assert!(build("alexnet").is_none());
    }

    #[test]
    fn every_paper_model_has_single_output() {
        for name in PAPER_MODELS {
            let g = build(name).unwrap();
            assert_eq!(g.outputs().len(), 1, "{name} output count");
        }
    }

    #[test]
    fn paper_models_sorted_by_size_sanity() {
        // SqueezeNet is the smallest, VGG-16 the largest by parameters.
        let params: Vec<u64> = PAPER_MODELS
            .iter()
            .map(|n| build(n).unwrap().total_params())
            .collect();
        let min = *params.iter().min().unwrap();
        let max = *params.iter().max().unwrap();
        assert_eq!(params[0], min); // squeezenet
        assert_eq!(params[1], max); // vgg16
    }
}
