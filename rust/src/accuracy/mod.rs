//! Accuracy exploration (§IV-C).
//!
//! The paper evaluates each candidate partitioning point's top-1 accuracy
//! under the bit widths of the platforms executing each segment (fake
//! quantization over ImageNet, optionally restored with QAT).
//!
//! Substitution (see DESIGN.md): ImageNet and pretrained weights are not
//! available in this environment, so zoo-scale accuracy uses an
//! analytical quantization-noise model calibrated against published
//! post-training-quantization results, while the *executable* tiny-CNN
//! path measures real top-1 through the AOT artifacts (quantized with the
//! L1 Pallas fake-quant kernel, optionally QAT-trained — see
//! `python/compile/model.py` and `examples/pipeline_serving.rs`).
//!
//! Analytical model: a layer executed at `b` bits injects quantization
//! noise with power ∝ 4^(8−b) relative to the 8-bit reference (6.02 dB
//! per bit). The network-level degradation is the MAC-weighted noise
//! share, and top-1 falls from the fp32 reference by the model's
//! measured 8-bit PTQ drop scaled by that share:
//!
//! ```text
//! noise   = Σ_i (macs_i / Σ macs) · 4^(8 − bits_i)
//! top1    = top1_fp32 − drop8 · noise^γ · (qat ? recovery : 1)
//! ```
//!
//! γ < 1 models the sub-linear growth of error with aggregate noise.

use crate::graph::{Graph, NodeId};
use std::ops::Range;

/// Per-model calibration constants: fp32 top-1 (torchvision reported) and
/// the 8-bit per-tensor PTQ top-1 drop (published measurements; larger
/// for depthwise-heavy / SiLU networks).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelAccuracy {
    /// Zoo model name this calibration belongs to.
    pub name: &'static str,
    /// Published fp32 top-1 (%).
    pub fp32_top1: f64,
    /// Published top-1 drop under 8-bit per-tensor PTQ (points).
    pub ptq8_drop: f64,
}

/// Published calibration points (percent top-1).
pub const MODEL_TABLE: [ModelAccuracy; 7] = [
    ModelAccuracy { name: "vgg16", fp32_top1: 71.59, ptq8_drop: 0.35 },
    ModelAccuracy { name: "resnet50", fp32_top1: 76.13, ptq8_drop: 0.70 },
    ModelAccuracy { name: "googlenet", fp32_top1: 69.78, ptq8_drop: 0.55 },
    ModelAccuracy { name: "squeezenet1_1", fp32_top1: 58.18, ptq8_drop: 2.20 },
    ModelAccuracy { name: "regnet_x_400mf", fp32_top1: 72.83, ptq8_drop: 1.10 },
    ModelAccuracy { name: "efficientnet_b0", fp32_top1: 77.69, ptq8_drop: 4.20 },
    // Executable model: reference comes from the build-time training run;
    // this entry is the fallback when artifacts are absent.
    ModelAccuracy { name: "tiny_cnn", fp32_top1: 90.0, ptq8_drop: 1.5 },
];

/// Sub-linear noise-to-drop exponent.
const GAMMA: f64 = 0.85;
/// Fraction of the PTQ drop remaining after 2-epoch QAT (§V-A).
const QAT_RECOVERY: f64 = 0.25;

/// Calibration constants for a zoo model, if published.
pub fn model_accuracy(name: &str) -> Option<&'static ModelAccuracy> {
    MODEL_TABLE.iter().find(|m| m.name == name)
}

/// Quantization-noise weight of bit width `b` relative to 8-bit
/// (6.02 dB/bit → power factor 4 per bit).
pub fn noise_weight(bits: u32) -> f64 {
    4f64.powi(8 - bits as i32)
}

/// Per-segment bit-width assignment over a schedule.
#[derive(Debug, Clone)]
pub struct BitAssignment {
    /// `(schedule range, bits)` — segments must tile the schedule.
    pub segments: Vec<(Range<usize>, u32)>,
}

impl BitAssignment {
    /// Two segments split after `cut_pos` with per-platform widths.
    pub fn two_way(cut_pos: usize, len: usize, bits_a: u32, bits_b: u32) -> Self {
        Self { segments: vec![(0..cut_pos + 1, bits_a), (cut_pos + 1..len, bits_b)] }
    }

    /// A single segment covering the whole schedule.
    pub fn uniform(len: usize, bits: u32) -> Self {
        Self { segments: vec![(0..len, bits)] }
    }
}

/// MAC-weighted aggregate quantization noise of an assignment,
/// normalized so an all-8-bit network scores 1.0.
pub fn aggregate_noise(g: &Graph, order: &[NodeId], assign: &BitAssignment) -> f64 {
    let total_macs: u64 = g.total_macs().max(1);
    let mut noise = 0.0;
    for (range, bits) in &assign.segments {
        let seg_macs: u64 = range.clone().map(|p| g.node(order[p]).macs).sum();
        noise += (seg_macs as f64 / total_macs as f64) * noise_weight(*bits);
    }
    noise
}

/// Predicted top-1 (percent) from a precomputed aggregate noise (the
/// explorer computes noise via prefix sums and calls this directly).
pub fn top1_from_noise(model: &ModelAccuracy, noise: f64, qat: bool) -> f64 {
    let drop = model.ptq8_drop * noise.powf(GAMMA) * if qat { QAT_RECOVERY } else { 1.0 };
    (model.fp32_top1 - drop).max(0.0)
}

/// Predicted top-1 (percent) for a model under a bit assignment.
pub fn top1(model: &ModelAccuracy, g: &Graph, order: &[NodeId], assign: &BitAssignment, qat: bool) -> f64 {
    top1_from_noise(model, aggregate_noise(g, order, assign), qat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topo::{topo_sort, TieBreak};
    use crate::zoo;

    fn setup(name: &str) -> (crate::graph::Graph, Vec<NodeId>, &'static ModelAccuracy) {
        let g = zoo::build(name).unwrap();
        let order = topo_sort(&g, TieBreak::Deterministic);
        let m = model_accuracy(name).unwrap();
        (g, order, m)
    }

    #[test]
    fn table_covers_all_zoo_models() {
        for name in zoo::names() {
            assert!(model_accuracy(name).is_some(), "{name} missing from MODEL_TABLE");
        }
    }

    #[test]
    fn noise_weights() {
        assert_eq!(noise_weight(8), 1.0);
        assert_eq!(noise_weight(16), 4f64.powi(-8));
        assert_eq!(noise_weight(4), 256.0);
    }

    #[test]
    fn all_8bit_equals_calibrated_drop() {
        let (g, order, m) = setup("resnet50");
        let a8 = BitAssignment::uniform(g.len(), 8);
        let t = top1(m, &g, &order, &a8, false);
        assert!((t - (m.fp32_top1 - m.ptq8_drop)).abs() < 1e-9);
    }

    #[test]
    fn sixteen_bit_is_nearly_lossless() {
        let (g, order, m) = setup("efficientnet_b0");
        let a16 = BitAssignment::uniform(g.len(), 16);
        let t = top1(m, &g, &order, &a16, false);
        assert!(m.fp32_top1 - t < 0.01, "16-bit drop {} too large", m.fp32_top1 - t);
    }

    #[test]
    fn later_partition_improves_top1() {
        // Paper Fig 2(c)/(f): A=16-bit EYR first, B=8-bit SMB after the
        // cut; moving the cut later puts more MACs on 16 bits.
        let (g, order, m) = setup("efficientnet_b0");
        let len = g.len();
        let early = top1(m, &g, &order, &BitAssignment::two_way(5, len, 16, 8), false);
        let mid = top1(m, &g, &order, &BitAssignment::two_way(len / 2, len, 16, 8), false);
        let late = top1(m, &g, &order, &BitAssignment::two_way(len - 2, len, 16, 8), false);
        assert!(early < mid && mid < late, "{early} {mid} {late}");
        // Bounded by the two pure cases.
        let all8 = top1(m, &g, &order, &BitAssignment::uniform(len, 8), false);
        let all16 = top1(m, &g, &order, &BitAssignment::uniform(len, 16), false);
        assert!(all8 <= early && late <= all16);
    }

    #[test]
    fn qat_recovers_most_of_the_drop() {
        let (g, order, m) = setup("squeezenet1_1");
        let a8 = BitAssignment::uniform(g.len(), 8);
        let without = top1(m, &g, &order, &a8, false);
        let with = top1(m, &g, &order, &a8, true);
        assert!(with > without);
        let recovered = (with - without) / (m.fp32_top1 - without);
        assert!((0.5..1.0).contains(&recovered), "recovered {recovered}");
    }

    #[test]
    fn efficientnet_most_sensitive() {
        let drops: Vec<f64> = ["vgg16", "resnet50", "efficientnet_b0"]
            .iter()
            .map(|n| {
                let (g, order, m) = setup(n);
                let a8 = BitAssignment::uniform(g.len(), 8);
                m.fp32_top1 - top1(m, &g, &order, &a8, false)
            })
            .collect();
        assert!(drops[2] > drops[1] && drops[1] > drops[0]);
    }
}
