//! Transmission-link model (latency + energy of moving intermediate
//! feature maps between platforms).
//!
//! The paper connects platforms via Gigabit Ethernet and uses the
//! open-source link model from CNNParted [9]. We implement the same
//! functional form: a fixed per-message base latency (stack + propagation),
//! per-packet overhead, payload serialization at the effective bandwidth,
//! and energy proportional to bytes on the wire plus per-packet framing
//! cost. All coefficients are configurable through `configs/*.toml`.

/// Parametric point-to-point link.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkModel {
    /// Link preset name (e.g. `gbe`).
    pub name: String,
    /// Effective payload bandwidth in bits/s (GbE ≈ 941 Mbit/s after
    /// TCP/IP + Ethernet framing overhead).
    pub bandwidth_bps: f64,
    /// Payload bytes per packet (1460 for TCP over Ethernet).
    pub mtu_payload: u64,
    /// Fixed software/propagation latency per transfer, seconds.
    pub base_latency_s: f64,
    /// Additional per-packet processing latency, seconds.
    pub per_packet_s: f64,
    /// Transmission + reception energy per payload byte, joules.
    pub energy_per_byte_j: f64,
    /// Per-packet framing/processing energy, joules.
    pub energy_per_packet_j: f64,
}

impl LinkModel {
    /// Gigabit Ethernet with CNNParted-style coefficients:
    /// 941 Mbit/s effective, 1460 B payload per frame, ~150 µs base
    /// latency (embedded TCP stack), 2 µs per-packet processing, and
    /// ~2 W combined TX+RX NIC power at line rate → ≈17 nJ/byte, with
    /// ~1 µJ per-packet framing energy.
    pub fn gigabit_ethernet() -> Self {
        Self {
            name: "gbe".to_string(),
            bandwidth_bps: 941e6,
            mtu_payload: 1460,
            base_latency_s: 150e-6,
            per_packet_s: 2e-6,
            energy_per_byte_j: 17e-9,
            energy_per_packet_j: 1e-6,
        }
    }

    /// An ideal infinite link (used by tests and as an ablation baseline).
    pub fn ideal() -> Self {
        Self {
            name: "ideal".to_string(),
            bandwidth_bps: f64::INFINITY,
            mtu_payload: u64::MAX,
            base_latency_s: 0.0,
            per_packet_s: 0.0,
            energy_per_byte_j: 0.0,
            energy_per_packet_j: 0.0,
        }
    }

    /// Number of packets for a payload.
    pub fn packets(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            0
        } else {
            bytes.div_ceil(self.mtu_payload)
        }
    }

    /// Transfer latency in seconds for `bytes` of payload. Zero bytes
    /// means no transfer (single-platform schedule) and costs nothing.
    pub fn latency_s(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let serialization = (bytes as f64 * 8.0) / self.bandwidth_bps;
        self.base_latency_s + self.packets(bytes) as f64 * self.per_packet_s + serialization
    }

    /// Transfer energy in joules for `bytes` of payload.
    pub fn energy_j(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        bytes as f64 * self.energy_per_byte_j
            + self.packets(bytes) as f64 * self.energy_per_packet_j
    }

    /// Sustained throughput ceiling imposed by the link for a repeating
    /// transfer of `bytes` (inferences/s) — the `1/d_link` term of
    /// Definition 4. In a pipelined system the base latency overlaps with
    /// the next transfer, so only serialization + packet processing
    /// bound the rate.
    pub fn throughput_ceiling(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return f64::INFINITY;
        }
        let occupancy =
            (bytes as f64 * 8.0) / self.bandwidth_bps + self.packets(bytes) as f64 * self.per_packet_s;
        1.0 / occupancy
    }

    /// Bandwidth required (bits/s) to sustain `rate` transfers of
    /// `bytes` per second — the quantity checked against link capacity
    /// when filtering candidate partitioning points.
    pub fn required_bps(bytes: u64, rate: f64) -> f64 {
        bytes as f64 * 8.0 * rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_monotonic_in_bytes() {
        let l = LinkModel::gigabit_ethernet();
        let mut prev = 0.0;
        for bytes in [1u64, 100, 1460, 1461, 10_000, 1_000_000] {
            let d = l.latency_s(bytes);
            assert!(d > prev, "latency not monotonic at {bytes}");
            prev = d;
        }
    }

    #[test]
    fn gbe_order_of_magnitude() {
        let l = LinkModel::gigabit_ethernet();
        // 1 MB payload: serialization ≈ 8.5 ms dominates.
        let d = l.latency_s(1_000_000);
        assert!((0.008..0.015).contains(&d), "1 MB GbE latency {d}");
        // VGG-16 conv1 fmap @16b = 64*224*224*2 B ≈ 6.4 MB ≈ 57 ms.
        let d = l.latency_s(64 * 224 * 224 * 2);
        assert!((0.04..0.08).contains(&d), "conv1 fmap latency {d}");
    }

    #[test]
    fn packet_boundary() {
        let l = LinkModel::gigabit_ethernet();
        assert_eq!(l.packets(1460), 1);
        assert_eq!(l.packets(1461), 2);
        assert_eq!(l.packets(14600), 10);
    }

    #[test]
    fn exact_mtu_multiples_add_no_phantom_packet() {
        let l = LinkModel::gigabit_ethernet();
        // Payload exactly at k × MTU is exactly k packets — the sim's
        // per-batch transfers land on these boundaries constantly
        // (batch × power-of-two feature maps).
        for k in [1u64, 2, 10, 1000] {
            assert_eq!(l.packets(k * l.mtu_payload), k, "k={k}");
            assert_eq!(l.packets(k * l.mtu_payload + 1), k + 1, "k={k}+1");
            assert_eq!(l.packets(k * l.mtu_payload - 1), k, "k={k}-1");
        }
        // One byte past the boundary costs exactly one extra packet's
        // processing latency plus one byte of serialization.
        let at = l.latency_s(2 * l.mtu_payload);
        let over = l.latency_s(2 * l.mtu_payload + 1);
        let expect = l.per_packet_s + 8.0 / l.bandwidth_bps;
        assert!((over - at - expect).abs() < 1e-12, "latency step {}", over - at);
        // Same for energy: one packet's framing plus one byte.
        let e_at = l.energy_j(2 * l.mtu_payload);
        let e_over = l.energy_j(2 * l.mtu_payload + 1);
        let e_expect = l.energy_per_packet_j + l.energy_per_byte_j;
        assert!((e_over - e_at - e_expect).abs() < 1e-15, "energy step {}", e_over - e_at);
        // And the pipelined ceiling drops when the extra packet appears.
        assert!(l.throughput_ceiling(l.mtu_payload + 1) < l.throughput_ceiling(l.mtu_payload));
    }

    #[test]
    fn zero_byte_transfer_is_free_everywhere() {
        // Single-platform schedules transfer nothing: every link
        // quantity must be exactly zero/identity, not epsilon.
        for l in [LinkModel::gigabit_ethernet(), LinkModel::ideal()] {
            assert_eq!(l.packets(0), 0, "{}", l.name);
            assert_eq!(l.latency_s(0), 0.0, "{}", l.name);
            assert_eq!(l.energy_j(0), 0.0, "{}", l.name);
            assert!(l.throughput_ceiling(0).is_infinite(), "{}", l.name);
        }
        assert_eq!(LinkModel::required_bps(0, 1000.0), 0.0);
    }

    #[test]
    fn energy_scales_linearly_in_payload() {
        let l = LinkModel::gigabit_ethernet();
        let e1 = l.energy_j(1460 * 100);
        let e2 = l.energy_j(1460 * 200);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn pipelined_ceiling_exceeds_one_shot_rate() {
        let l = LinkModel::gigabit_ethernet();
        let bytes = 500_000;
        let one_shot = 1.0 / l.latency_s(bytes);
        let ceiling = l.throughput_ceiling(bytes);
        assert!(ceiling > one_shot);
    }

    #[test]
    fn ideal_link_is_free() {
        let l = LinkModel::ideal();
        assert_eq!(l.latency_s(123456), 0.0);
        assert_eq!(l.energy_j(123456), 0.0);
    }

    #[test]
    fn required_bandwidth() {
        // 100 KB at 30 inf/s = 24 Mbit/s.
        let bps = LinkModel::required_bps(100_000, 30.0);
        assert!((bps - 24e6).abs() < 1.0);
    }
}
