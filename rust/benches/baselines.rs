//! Bench: baseline-scheduler comparison — the quantitative version of
//! the paper's related-work argument (§II, Table I; §V-B: "This shows
//! the advantages of our approach over AxoNN and CNNParted, which do not
//! explicitly include throughput in their search").
//!
//!     cargo bench --bench baselines

#[path = "common/mod.rs"]
mod common;

use partir::config::SystemConfig;
use partir::explorer::{baselines, ExploreRequest};
use partir::zoo;

fn main() {
    let mut sys = SystemConfig::paper_two_platform();
    if common::fast_mode() {
        sys.search.victory = 15;
        sys.search.max_samples = 150;
    }
    for model in ["resnet50", "efficientnet_b0", "squeezenet1_1"] {
        common::section(&format!("{model}: what each strategy's choice costs"));
        let g = zoo::build(model).unwrap();
        let ex = ExploreRequest::chain().run(&g, &sys);
        let rows = baselines::compare_all(&ex);
        println!(
            "{:<20} {:<16} {:>10} {:>11} {:>13} {:>7}",
            "strategy", "chosen point", "latency", "energy", "throughput", "top-1"
        );
        let ours_tput = rows
            .iter()
            .find(|r| r.name == "ours(throughput)")
            .map(|r| r.throughput)
            .unwrap_or(0.0);
        for r in &rows {
            let loss = if ours_tput > 0.0 {
                format!("{:+.0}%", 100.0 * (r.throughput - ours_tput) / ours_tput)
            } else {
                "-".into()
            };
            println!(
                "{:<20} {:<16} {:>10} {:>11} {:>9.1} i/s {:>6.2}%  (tput vs ours {loss})",
                r.name,
                r.label,
                common::fmt(r.latency_s),
                partir::util::units::fmt_energy_j(r.energy_j),
                r.throughput,
                r.top1,
            );
        }
    }
}
