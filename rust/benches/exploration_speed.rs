//! Bench: §V-B framework runtime — the paper reports "graph analysis and
//! hardware evaluation together take approx. 40 min for EfficientNet-B0"
//! on a 64-core EPYC (running real Timeloop). This bench reports the
//! same breakdown for our analytical substrate, per model, and compares
//! the serial (`jobs = 1`) against the multi-core exploration path
//! (which must be bit-identical, only faster).
//!
//!     cargo bench --bench exploration_speed

#[path = "common/mod.rs"]
mod common;

use partir::config::SystemConfig;
use partir::explorer::{explore_two_platform, multi};
use partir::graph::Graph;
use partir::util::parallel::default_jobs;
use partir::zoo;
use std::time::Instant;

fn main() {
    let jobs = default_jobs();
    let mut sys = SystemConfig::paper_two_platform();
    if common::fast_mode() {
        sys.search.victory = 15;
        sys.search.max_samples = 150;
    }
    let mut serial = sys.clone();
    serial.jobs = 1;
    let mut par = sys.clone();
    par.jobs = jobs;

    common::section("exploration wall-time breakdown per model (two-platform DSE)");
    println!(
        "{:<18} {:>8} {:>10} {:>12} {:>10} {:>10} {:>10} {:>8}",
        "model", "layers", "hw-eval", "candidates", "nsga-ii", "serial", "par", "speedup"
    );
    for name in zoo::PAPER_MODELS {
        let g = zoo::build(name).unwrap();
        let ex_serial = explore_two_platform(&g, &serial);
        let ex_par = explore_two_platform(&g, &par);
        // Parallel runs must be byte-identical to serial — fail loudly
        // here rather than publish a speedup for a different answer.
        assert_eq!(ex_serial.pareto, ex_par.pareto, "{name}: parallel run diverged");
        assert_eq!(ex_serial.favorite, ex_par.favorite, "{name}: parallel run diverged");
        println!(
            "{:<18} {:>8} {:>10} {:>12} {:>10} {:>10} {:>10} {:>7.2}x",
            name,
            g.len(),
            common::fmt(ex_par.timing.hw_eval_s),
            common::fmt(ex_par.timing.candidates_s),
            common::fmt(ex_par.timing.nsga_s),
            common::fmt(ex_serial.timing.total_s),
            common::fmt(ex_par.timing.total_s),
            ex_serial.timing.total_s / ex_par.timing.total_s.max(1e-12),
        );
    }

    common::section(format!(
        "full PAPER_MODELS sweep: serial loop vs shared-pool explore_many ({jobs} jobs)"
    )
    .as_str());
    let graphs: Vec<Graph> = zoo::PAPER_MODELS.iter().map(|m| zoo::build(m).unwrap()).collect();
    let t0 = Instant::now();
    for g in &graphs {
        std::hint::black_box(explore_two_platform(g, &serial));
    }
    let serial_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    std::hint::black_box(multi::explore_many(&graphs, &par));
    let par_s = t1.elapsed().as_secs_f64();
    println!("{:<28} {:>10}", "serial loop", common::fmt(serial_s));
    println!("{:<28} {:>10}", "explore_many (shared cache)", common::fmt(par_s));
    println!(
        "sweep speedup: {:.2}x on {jobs} hardware threads (acceptance target: >= 1.8x on 4 cores)",
        serial_s / par_s.max(1e-12)
    );

    println!(
        "\npaper reference: graph analysis + HW evaluation ~ 40 min for \
         EfficientNet-B0 (real Timeloop); retraining ~ 1 h per point when enabled.\n\
         Our per-layer cost cache + prefix-sum evaluation brings the same pipeline \
         to sub-second totals; QAT remains the dominant cost and lives in \
         `make artifacts` (~2 min, amortized once)."
    );
}
