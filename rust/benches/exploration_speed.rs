//! Bench: §V-B framework runtime — the paper reports "graph analysis and
//! hardware evaluation together take approx. 40 min for EfficientNet-B0"
//! on a 64-core EPYC (running real Timeloop). This bench reports the
//! same breakdown for our analytical substrate, per model.
//!
//!     cargo bench --bench exploration_speed

#[path = "common/mod.rs"]
mod common;

use partir::config::SystemConfig;
use partir::explorer::explore_two_platform;
use partir::zoo;

fn main() {
    common::section("exploration wall-time breakdown per model (two-platform DSE)");
    let mut sys = SystemConfig::paper_two_platform();
    if common::fast_mode() {
        sys.search.victory = 15;
        sys.search.max_samples = 150;
    }
    println!(
        "{:<18} {:>8} {:>10} {:>12} {:>10} {:>10}",
        "model", "layers", "hw-eval", "candidates", "nsga-ii", "total"
    );
    for name in zoo::PAPER_MODELS {
        let g = zoo::build(name).unwrap();
        let ex = explore_two_platform(&g, &sys);
        println!(
            "{:<18} {:>8} {:>10} {:>12} {:>10} {:>10}",
            name,
            g.len(),
            common::fmt(ex.timing.hw_eval_s),
            common::fmt(ex.timing.candidates_s),
            common::fmt(ex.timing.nsga_s),
            common::fmt(ex.timing.total_s)
        );
    }
    println!(
        "\npaper reference: graph analysis + HW evaluation ~ 40 min for \
         EfficientNet-B0 (real Timeloop); retraining ~ 1 h per point when enabled.\n\
         Our per-layer cost cache + prefix-sum evaluation brings the same pipeline \
         to sub-second totals; QAT remains the dominant cost and lives in \
         `make artifacts` (~2 min, amortized once)."
    );
}
