//! Bench: §V-B framework runtime — the paper reports "graph analysis and
//! hardware evaluation together take approx. 40 min for EfficientNet-B0"
//! on a 64-core EPYC (running real Timeloop). This bench reports the
//! same breakdown for our analytical substrate, per model, compares the
//! serial (`jobs = 1`) against the multi-core exploration path (which
//! must be bit-identical, only faster), and measures the persistent
//! cost cache: a cold PAPER_MODELS sweep is saved to disk, reloaded,
//! and re-run warm — the warm sweep must perform **zero** mapper
//! searches and reproduce identical fronts (acceptance: warm < 5 s).
//! Results land in `BENCH_explore.json`.
//!
//!     cargo bench --bench exploration_speed

#[path = "common/mod.rs"]
mod common;

use partir::config::SystemConfig;
use partir::explorer::ExploreRequest;
use partir::graph::Graph;
use partir::hw::{CacheLoad, CostCache};
use partir::util::json::{obj, Json};
use partir::util::parallel::default_jobs;
use partir::zoo;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let jobs = default_jobs();
    let mut sys = SystemConfig::paper_two_platform();
    if common::fast_mode() {
        sys.search.victory = 15;
        sys.search.max_samples = 150;
    }
    let mut serial = sys.clone();
    serial.jobs = 1;
    let mut par = sys.clone();
    par.jobs = jobs;

    common::section("exploration wall-time breakdown per model (two-platform DSE)");
    println!(
        "{:<18} {:>8} {:>10} {:>12} {:>10} {:>10} {:>10} {:>8}",
        "model", "layers", "hw-eval", "candidates", "nsga-ii", "serial", "par", "speedup"
    );
    let mut per_model: Vec<Json> = Vec::new();
    for name in zoo::PAPER_MODELS {
        let g = zoo::build(name).unwrap();
        let ex_serial = ExploreRequest::chain().run(&g, &serial);
        let ex_par = ExploreRequest::chain().run(&g, &par);
        // Parallel runs must be byte-identical to serial — fail loudly
        // here rather than publish a speedup for a different answer.
        assert_eq!(ex_serial.pareto, ex_par.pareto, "{name}: parallel run diverged");
        assert_eq!(ex_serial.favorite, ex_par.favorite, "{name}: parallel run diverged");
        println!(
            "{:<18} {:>8} {:>10} {:>12} {:>10} {:>10} {:>10} {:>7.2}x",
            name,
            g.len(),
            common::fmt(ex_par.timing.hw_eval_s),
            common::fmt(ex_par.timing.candidates_s),
            common::fmt(ex_par.timing.nsga_s),
            common::fmt(ex_serial.timing.total_s),
            common::fmt(ex_par.timing.total_s),
            ex_serial.timing.total_s / ex_par.timing.total_s.max(1e-12),
        );
        per_model.push(obj(vec![
            ("model", Json::from(name)),
            ("layers", Json::from(g.len())),
            ("hw_eval_s", Json::from(ex_par.timing.hw_eval_s)),
            ("candidates_s", Json::from(ex_par.timing.candidates_s)),
            ("nsga_s", Json::from(ex_par.timing.nsga_s)),
            ("serial_s", Json::from(ex_serial.timing.total_s)),
            ("par_s", Json::from(ex_par.timing.total_s)),
        ]));
    }

    common::section(format!(
        "full PAPER_MODELS sweep: serial loop vs shared-pool run_many ({jobs} jobs)"
    )
    .as_str());
    let graphs: Vec<Graph> = zoo::PAPER_MODELS.iter().map(|m| zoo::build(m).unwrap()).collect();
    let t0 = Instant::now();
    for g in &graphs {
        std::hint::black_box(ExploreRequest::chain().run(g, &serial));
    }
    let serial_s = t0.elapsed().as_secs_f64();
    // The parallel sweep doubles as the *cold* run of the persistence
    // section below: its cache is saved and reloaded for the warm rerun.
    let cold_cache = Arc::new(CostCache::new());
    let t1 = Instant::now();
    let cold = ExploreRequest::chain().with_cache(Arc::clone(&cold_cache)).run_many(&graphs, &par);
    let cold_s = t1.elapsed().as_secs_f64();
    println!("{:<28} {:>10}", "serial loop", common::fmt(serial_s));
    println!("{:<28} {:>10}", "run_many (shared cache)", common::fmt(cold_s));
    println!(
        "sweep speedup: {:.2}x on {jobs} hardware threads (acceptance target: >= 1.8x on 4 cores)",
        serial_s / cold_s.max(1e-12)
    );

    common::section("persistent cost cache: cold sweep vs warm (loaded from disk) rerun");
    let dir = std::env::temp_dir().join(format!("partir_bench_cache_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    cold_cache.save_to(&dir, &par.search).expect("cache save failed");
    let (warm_cache, status) = CostCache::load_from(&dir, &par.search);
    assert!(
        matches!(status, CacheLoad::Loaded(_)),
        "freshly saved cache failed to load: {status:?}"
    );
    let warm_cache = Arc::new(warm_cache);
    let t2 = Instant::now();
    let warm = ExploreRequest::chain().with_cache(Arc::clone(&warm_cache)).run_many(&graphs, &par);
    let warm_s = t2.elapsed().as_secs_f64();
    assert_eq!(warm_cache.misses(), 0, "warm sweep re-ran layer evaluations");
    for (a, b) in cold.iter().zip(&warm) {
        assert_eq!(a.pareto, b.pareto, "{}: warm front diverged", a.model);
        assert_eq!(a.favorite, b.favorite, "{}: warm favorite diverged", a.model);
    }
    std::fs::remove_dir_all(&dir).ok();
    println!(
        "{:<28} {:>10}  ({} entries persisted)",
        "cold sweep",
        common::fmt(cold_s),
        cold_cache.len()
    );
    println!(
        "{:<28} {:>10}  (0 mapper searches, fronts identical)",
        "warm sweep",
        common::fmt(warm_s)
    );
    println!(
        "warm speedup: {:.1}x (acceptance: warm rerun < 5 s in full mode)",
        cold_s / warm_s.max(1e-12)
    );

    common::write_bench_json(
        "explore",
        &obj(vec![
            ("bench", Json::from("exploration_speed")),
            ("fast_mode", Json::from(common::fast_mode())),
            ("jobs", Json::from(jobs)),
            ("per_model", Json::Arr(per_model)),
            ("serial_sweep_s", Json::from(serial_s)),
            ("cold_sweep_s", Json::from(cold_s)),
            ("sweep_speedup", Json::from(serial_s / cold_s.max(1e-12))),
            ("warm_sweep_s", Json::from(warm_s)),
            ("warm_speedup", Json::from(cold_s / warm_s.max(1e-12))),
            ("warm_misses", Json::from(warm_cache.misses())),
            ("cache_entries", Json::from(cold_cache.len())),
        ]),
    );

    println!(
        "\npaper reference: graph analysis + HW evaluation ~ 40 min for \
         EfficientNet-B0 (real Timeloop); retraining ~ 1 h per point when enabled.\n\
         Our per-layer cost cache + prefix-sum evaluation brings the same pipeline \
         to sub-second totals; the persistent cache makes reruns pure lookups. \
         QAT remains the dominant cost and lives in `make artifacts` (~2 min, \
         amortized once)."
    );
}
