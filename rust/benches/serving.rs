//! Bench: the discrete-event serving simulator at millions-of-requests
//! scale — the runtime counterpart of the paper's steady-state
//! throughput claims (§V-B "47.5% EfficientNet-B0 gain" shape), plus
//! batching-policy and scenario sweeps.
//!
//!     cargo bench --bench serving
//!
//! Asserts (also under PARTIR_BENCH_FAST=1 in CI):
//!   * a 1M-request Poisson scenario simulates in < 30 s wall-clock;
//!   * repeated runs are bit-identical (fingerprints match);
//!   * `evaluate_front` is bit-identical across worker counts;
//!   * the partitioned deployment out-serves the best single platform.
//! Emits machine-readable `BENCH_sim.json`.

#[path = "common/mod.rs"]
mod common;

use partir::config::SystemConfig;
use partir::coordinator::BatchPolicy;
use partir::explorer::explore_two_platform;
use partir::sim::{self, Deployment, Scenario, SimCfg};
use partir::util::json::{obj, Json};
use partir::util::parallel::default_jobs;
use partir::zoo;
use std::time::Instant;

fn main() {
    let fast = common::fast_mode();
    // The headline stays 1M requests even in CI fast mode — simulating
    // them cheaply is the whole point of the subsystem.
    let requests = 1_000_000usize;

    common::section("explore efficientnet_b0 (the simulator's input)");
    let mut sys = SystemConfig::paper_two_platform();
    sys.search.victory = 20;
    sys.search.max_samples = 200;
    sys.jobs = default_jobs();
    let g = zoo::build("efficientnet_b0").unwrap();
    let t0 = Instant::now();
    let ex = explore_two_platform(&g, &sys);
    let explore_s = t0.elapsed().as_secs_f64();
    println!(
        "explored {} candidates in {}",
        ex.candidates.len(),
        common::fmt(explore_s)
    );
    let single = ex
        .candidates
        .iter()
        .filter(|c| c.partitions == 1 && c.feasible())
        .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
        .expect("a single-platform candidate");
    let split = ex
        .candidates
        .iter()
        .filter(|c| c.partitions == 2 && c.feasible())
        .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
        .expect("a partitioned candidate");
    println!(
        "analytic: split '{}' {:.1} inf/s vs single '{}' {:.1} inf/s",
        split.label, split.throughput, single.label, single.throughput
    );

    // Offered load: 1.2x what the best single platform can serve, so
    // the comparison below happens in the regime the paper talks about.
    let rate = 1.2 * single.throughput;
    let cfg = SimCfg::from_system(&sys);

    common::section(&format!("{requests} request Poisson storm @ {rate:.0}/s"));
    let storm = Scenario::steady(requests, rate);
    let dep_split = Deployment::from_candidate(split, &sys);
    let t1 = Instant::now();
    let r_split = sim::simulate(&dep_split, &cfg, &storm);
    let sim_s = t1.elapsed().as_secs_f64();
    println!(
        "split:  {} requests in {} real ({:.2e} events/s, {:.2e} req/s simulated)",
        requests,
        common::fmt(sim_s),
        r_split.events as f64 / sim_s,
        requests as f64 / sim_s,
    );
    print!("{}", r_split.render());
    assert!(sim_s < 30.0, "1M-request simulation took {sim_s:.1}s (budget: 30s)");
    let r_again = sim::simulate(&dep_split, &cfg, &storm);
    assert_eq!(
        r_split.fingerprint(),
        r_again.fingerprint(),
        "simulation is not deterministic"
    );

    let dep_single = Deployment::from_candidate(single, &sys);
    let r_single = sim::simulate(&dep_single, &cfg, &storm);
    let gain = 100.0 * (r_split.throughput() - r_single.throughput())
        / r_single.throughput();
    println!(
        "single: {:.1} inf/s  → simulated partitioning gain {gain:+.1}%",
        r_single.throughput()
    );
    assert!(
        r_split.throughput() > r_single.throughput(),
        "partitioned deployment lost to single platform in simulation"
    );

    common::section("batching-policy sweep (split deployment)");
    let sweep_req = if fast { 100_000 } else { 500_000 };
    let sweep = Scenario::steady(sweep_req, rate);
    println!(
        "{:>6} {:>13} {:>10} {:>10} {:>9}",
        "batch", "throughput", "p50", "p99", "dropped"
    );
    let mut sweep_rows = Vec::new();
    for max_batch in [1usize, 2, 4, 8, 16, 32] {
        let mut c = cfg;
        c.batch = BatchPolicy::new(max_batch, cfg.batch.max_wait);
        let r = sim::simulate(&dep_split, &c, &sweep);
        println!(
            "{max_batch:>6} {:>9.1} i/s {:>10} {:>10} {:>9}",
            r.throughput(),
            common::fmt(r.pipeline.latency_percentile(50.0)),
            common::fmt(r.pipeline.latency_percentile(99.0)),
            r.dropped
        );
        sweep_rows.push(obj(vec![
            ("max_batch", Json::from(max_batch)),
            ("throughput", Json::from(r.throughput())),
            ("p99_s", Json::from(r.pipeline.latency_percentile(99.0))),
            ("dropped", Json::from(r.dropped)),
        ]));
    }

    common::section("scenario catalog (split deployment, 100 ms SLO)");
    println!(
        "{:>9} {:>13} {:>13} {:>9} {:>9}",
        "scenario", "goodput", "throughput", "dropped", "slo-miss"
    );
    let mut scen_rows = Vec::new();
    for name in Scenario::builtin_names() {
        let mut sc = Scenario::by_name(name, sweep_req, rate).unwrap();
        sc.deadline_s = Some(0.1);
        let r = sim::simulate(&dep_split, &cfg, &sc);
        println!(
            "{name:>9} {:>9.1} i/s {:>9.1} i/s {:>9} {:>9}",
            r.goodput,
            r.throughput(),
            r.dropped,
            r.slo_violations
        );
        scen_rows.push(obj(vec![
            ("scenario", Json::from(*name)),
            ("goodput", Json::from(r.goodput)),
            ("throughput", Json::from(r.throughput())),
            ("dropped", Json::from(r.dropped)),
            ("slo_violations", Json::from(r.slo_violations)),
        ]));
    }

    common::section("evaluate_front across --jobs (must be bit-identical)");
    let front_req = if fast { 50_000 } else { 200_000 };
    let front_sc = Scenario::steady(front_req, rate);
    let t2 = Instant::now();
    let serial = sim::evaluate_front(&ex, &sys, &front_sc, &cfg, 1);
    let front_serial_s = t2.elapsed().as_secs_f64();
    let jobs = default_jobs();
    let t3 = Instant::now();
    let par = sim::evaluate_front(&ex, &sys, &front_sc, &cfg, jobs);
    let front_par_s = t3.elapsed().as_secs_f64();
    assert_eq!(serial, par, "evaluate_front changed under jobs={jobs}");
    println!(
        "{} candidates × {front_req} requests: serial {} vs {jobs} jobs {} ({:.2}x)",
        serial.len(),
        common::fmt(front_serial_s),
        common::fmt(front_par_s),
        front_serial_s / front_par_s.max(1e-12)
    );
    print!("{}", sim::render_ranking(&serial));

    common::write_bench_json(
        "sim",
        &obj(vec![
            ("bench", Json::from("serving")),
            ("fast_mode", Json::from(fast)),
            ("requests", Json::from(requests)),
            ("explore_s", Json::from(explore_s)),
            ("sim_s", Json::from(sim_s)),
            ("events", Json::from(r_split.events)),
            ("events_per_s", Json::from(r_split.events as f64 / sim_s)),
            ("req_per_s_simulated", Json::from(requests as f64 / sim_s)),
            ("split_label", Json::from(split.label.as_str())),
            ("sim_split_ips", Json::from(r_split.throughput())),
            ("sim_single_ips", Json::from(r_single.throughput())),
            ("sim_gain_pct", Json::from(gain)),
            ("fingerprint", Json::from(format!("{:016x}", r_split.fingerprint()))),
            ("batch_sweep", Json::Arr(sweep_rows)),
            ("scenarios", Json::Arr(scen_rows)),
            ("front_candidates", Json::from(serial.len())),
            ("front_serial_s", Json::from(front_serial_s)),
            ("front_par_s", Json::from(front_par_s)),
            ("front_jobs", Json::from(jobs)),
        ]),
    );
}
