//! Bench: the discrete-event serving simulator at millions-of-requests
//! scale — the runtime counterpart of the paper's steady-state
//! throughput claims (§V-B "47.5% EfficientNet-B0 gain" shape), plus
//! batching-policy and scenario sweeps.
//!
//!     cargo bench --bench serving
//!
//! Asserts (also under PARTIR_BENCH_FAST=1 in CI):
//!   * a 1M-request Poisson scenario simulates in < 30 s wall-clock;
//!   * repeated runs are bit-identical (fingerprints match);
//!   * `evaluate_front` is bit-identical across worker counts;
//!   * the partitioned deployment out-serves the best single platform;
//!   * on the 16-node mixed EYR/SMB cluster preset, the best replicated
//!     plan achieves strictly higher simulated goodput than the best
//!     unreplicated pipeline split for EfficientNet-B0 AND ResNet-50;
//!   * under the `failover` preset the adaptive controller strictly
//!     out-serves the static favorite, pays nonzero migration cost,
//!     and is bit-identical across worker counts;
//!   * a live observability registry (counters + spans) leaves the
//!     1M-request storm's fingerprint — and therefore its goodput —
//!     bit-identical to the bare run;
//!   * on the 16-node cluster preset, jointly co-scheduled 2-tenant
//!     serving achieves strictly higher aggregate goodput than the best
//!     sequential per-tenant plans (each model taking the cluster
//!     exclusively, back to back), and the multi-tenant ranking is
//!     bit-identical across worker counts;
//!   * the fault-ensemble robustness report over the 16-node serving
//!     set is bit-identical across worker counts and reruns, the
//!     robust favorite dominates every scored plan on worst-case
//!     goodput — strictly beating the throughput favorite whenever the
//!     ensemble targets it while another plan escapes — and gives back
//!     at most half the throughput favorite's fault-free goodput.
//! Emits machine-readable `BENCH_sim.json`, `BENCH_cluster.json`
//! (goodput scaling curve over the 16/32/64-node presets),
//! `BENCH_adaptive.json` (adaptive-vs-static-vs-oracle goodput),
//! `BENCH_obs.json` (instrumentation overhead) plus a sample Perfetto
//! trace `BENCH_obs_trace.json` from an instrumented failover run,
//! `BENCH_multitenant.json` (joint-vs-sequential goodput + fairness
//! sweep), and `BENCH_robustness.json` (worst-case/CVaR goodput of the
//! robust vs throughput favorites under the fault ensemble).

#[path = "common/mod.rs"]
mod common;

use partir::config::{FairnessPolicy, SystemConfig, TenantSet, TenantSpec};
use partir::coordinator::BatchPolicy;
use partir::explorer::{CandidateMetrics, Exploration, ExploreRequest};
use partir::hw::{presets::CLUSTER_SIZES, CostCache};
use partir::obs::Registry;
use partir::sim::{self, ControllerMode, Deployment, Scenario, SimCfg};
use partir::util::json::{obj, Json};
use partir::util::parallel::default_jobs;
use partir::zoo;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let fast = common::fast_mode();
    // The headline stays 1M requests even in CI fast mode — simulating
    // them cheaply is the whole point of the subsystem.
    let requests = 1_000_000usize;

    common::section("explore efficientnet_b0 (the simulator's input)");
    let mut sys = SystemConfig::paper_two_platform();
    sys.search.victory = 20;
    sys.search.max_samples = 200;
    sys.jobs = default_jobs();
    let g = zoo::build("efficientnet_b0").unwrap();
    let t0 = Instant::now();
    let ex = ExploreRequest::chain().run(&g, &sys);
    let explore_s = t0.elapsed().as_secs_f64();
    println!(
        "explored {} candidates in {}",
        ex.candidates.len(),
        common::fmt(explore_s)
    );
    let single = ex
        .candidates
        .iter()
        .filter(|c| c.partitions == 1 && c.feasible())
        .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
        .expect("a single-platform candidate");
    let split = ex
        .candidates
        .iter()
        .filter(|c| c.partitions == 2 && c.feasible())
        .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
        .expect("a partitioned candidate");
    println!(
        "analytic: split '{}' {:.1} inf/s vs single '{}' {:.1} inf/s",
        split.label, split.throughput, single.label, single.throughput
    );

    // Offered load: 1.2x what the best single platform can serve, so
    // the comparison below happens in the regime the paper talks about.
    let rate = 1.2 * single.throughput;
    let cfg = SimCfg::from_system(&sys);

    common::section(&format!("{requests} request Poisson storm @ {rate:.0}/s"));
    let storm = Scenario::steady(requests, rate);
    let dep_split = Deployment::from_candidate(split, &sys);
    let t1 = Instant::now();
    let r_split = sim::simulate(&dep_split, &cfg, &storm);
    let sim_s = t1.elapsed().as_secs_f64();
    println!(
        "split:  {} requests in {} real ({:.2e} events/s, {:.2e} req/s simulated)",
        requests,
        common::fmt(sim_s),
        r_split.events as f64 / sim_s,
        requests as f64 / sim_s,
    );
    print!("{}", r_split.render());
    assert!(sim_s < 30.0, "1M-request simulation took {sim_s:.1}s (budget: 30s)");
    let r_again = sim::simulate(&dep_split, &cfg, &storm);
    assert_eq!(
        r_split.fingerprint(),
        r_again.fingerprint(),
        "simulation is not deterministic"
    );

    let dep_single = Deployment::from_candidate(single, &sys);
    let r_single = sim::simulate(&dep_single, &cfg, &storm);
    let gain = 100.0 * (r_split.throughput() - r_single.throughput())
        / r_single.throughput();
    println!(
        "single: {:.1} inf/s  → simulated partitioning gain {gain:+.1}%",
        r_single.throughput()
    );
    assert!(
        r_split.throughput() > r_single.throughput(),
        "partitioned deployment lost to single platform in simulation"
    );

    common::section("batching-policy sweep (split deployment)");
    let sweep_req = if fast { 100_000 } else { 500_000 };
    let sweep = Scenario::steady(sweep_req, rate);
    println!(
        "{:>6} {:>13} {:>10} {:>10} {:>9}",
        "batch", "throughput", "p50", "p99", "dropped"
    );
    let mut sweep_rows = Vec::new();
    for max_batch in [1usize, 2, 4, 8, 16, 32] {
        let mut c = cfg;
        c.batch = BatchPolicy::new(max_batch, cfg.batch.max_wait);
        let r = sim::simulate(&dep_split, &c, &sweep);
        println!(
            "{max_batch:>6} {:>9.1} i/s {:>10} {:>10} {:>9}",
            r.throughput(),
            common::fmt(r.pipeline.latency_percentile(50.0)),
            common::fmt(r.pipeline.latency_percentile(99.0)),
            r.dropped
        );
        sweep_rows.push(obj(vec![
            ("max_batch", Json::from(max_batch)),
            ("throughput", Json::from(r.throughput())),
            ("p99_s", Json::from(r.pipeline.latency_percentile(99.0))),
            ("dropped", Json::from(r.dropped)),
        ]));
    }

    common::section("scenario catalog (split deployment, 100 ms SLO)");
    println!(
        "{:>9} {:>13} {:>13} {:>9} {:>9}",
        "scenario", "goodput", "throughput", "dropped", "slo-miss"
    );
    let mut scen_rows = Vec::new();
    for name in Scenario::builtin_names() {
        let mut sc = Scenario::by_name(name, sweep_req, rate).unwrap();
        sc.deadline_s = Some(0.1);
        let r = sim::simulate(&dep_split, &cfg, &sc);
        println!(
            "{name:>9} {:>9.1} i/s {:>9.1} i/s {:>9} {:>9}",
            r.goodput,
            r.throughput(),
            r.dropped,
            r.slo_violations
        );
        scen_rows.push(obj(vec![
            ("scenario", Json::from(*name)),
            ("goodput", Json::from(r.goodput)),
            ("throughput", Json::from(r.throughput())),
            ("dropped", Json::from(r.dropped)),
            ("slo_violations", Json::from(r.slo_violations)),
        ]));
    }

    common::section("evaluate_front across --jobs (must be bit-identical)");
    let front_req = if fast { 50_000 } else { 200_000 };
    let front_sc = Scenario::steady(front_req, rate);
    let t2 = Instant::now();
    let serial = sim::evaluate_front(&ex, &sys, &front_sc, &cfg, 1);
    let front_serial_s = t2.elapsed().as_secs_f64();
    let jobs = default_jobs();
    let t3 = Instant::now();
    let par = sim::evaluate_front(&ex, &sys, &front_sc, &cfg, jobs);
    let front_par_s = t3.elapsed().as_secs_f64();
    assert_eq!(serial, par, "evaluate_front changed under jobs={jobs}");
    println!(
        "{} candidates × {front_req} requests: serial {} vs {jobs} jobs {} ({:.2}x)",
        serial.len(),
        common::fmt(front_serial_s),
        common::fmt(front_par_s),
        front_serial_s / front_par_s.max(1e-12)
    );
    print!("{}", sim::render_ranking(&serial));

    common::write_bench_json(
        "sim",
        &obj(vec![
            ("bench", Json::from("serving")),
            ("fast_mode", Json::from(fast)),
            ("requests", Json::from(requests)),
            ("explore_s", Json::from(explore_s)),
            ("sim_s", Json::from(sim_s)),
            ("events", Json::from(r_split.events)),
            ("events_per_s", Json::from(r_split.events as f64 / sim_s)),
            ("req_per_s_simulated", Json::from(requests as f64 / sim_s)),
            ("split_label", Json::from(split.label.as_str())),
            ("sim_split_ips", Json::from(r_split.throughput())),
            ("sim_single_ips", Json::from(r_single.throughput())),
            ("sim_gain_pct", Json::from(gain)),
            ("fingerprint", Json::from(format!("{:016x}", r_split.fingerprint()))),
            ("batch_sweep", Json::Arr(sweep_rows)),
            ("scenarios", Json::Arr(scen_rows)),
            ("front_candidates", Json::from(serial.len())),
            ("front_serial_s", Json::from(front_serial_s)),
            ("front_par_s", Json::from(front_par_s)),
            ("front_jobs", Json::from(jobs)),
        ]),
    );

    // -----------------------------------------------------------------
    // Cluster-scale replication
    // -----------------------------------------------------------------
    common::section("cluster replication: 16-node mixed EYR/SMB preset (acceptance)");
    let cluster_requests = if fast { 200_000 } else { 1_000_000 };
    // One layer-cost cache across every cluster exploration: all presets
    // reuse the same two accelerator design points.
    let shared = Arc::new(CostCache::new());
    // Best feasible pipeline split (>= 2 stages) by analytic throughput.
    let best_split = |ex: &Exploration| -> CandidateMetrics {
        ex.candidates
            .iter()
            .filter(|c| c.feasible() && c.partitions >= 2)
            .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
            .cloned()
            .expect("a feasible pipeline split")
    };
    let mut accept_rows = Vec::new();
    for model in ["efficientnet_b0", "resnet50"] {
        let gm = zoo::build(model).unwrap();
        let mut csys = SystemConfig::cluster(16);
        csys.search.victory = 20;
        csys.search.max_samples = 200;
        csys.jobs = default_jobs();
        // Unreplicated reference: same cluster, replication stripped.
        let mut base_sys = csys.clone();
        base_sys.replication = None;
        let base_ex = ExploreRequest::chain().with_cache(Arc::clone(&shared)).run(&gm, &base_sys);
        let rep_ex = ExploreRequest::chain().with_cache(Arc::clone(&shared)).run(&gm, &csys);
        let base_best = best_split(&base_ex);
        let rep_best = best_split(&rep_ex);
        let max_rep = rep_best.plan.iter().map(|p| p.replicas).max().unwrap_or(1);
        // Storm above the unreplicated split's capacity; both sides see
        // the exact same arrival trace (same scenario + seed).
        let rate = 1.5 * base_best.throughput;
        let storm = Scenario::steady(cluster_requests, rate);
        let ccfg = SimCfg::from_system(&csys);
        let r_base = sim::simulate(&Deployment::from_candidate(&base_best, &csys), &ccfg, &storm);
        let r_rep = sim::simulate(&Deployment::from_candidate(&rep_best, &csys), &ccfg, &storm);
        println!(
            "{model:<16} offered {rate:>8.0}/s  unreplicated '{}' {:>8.1} i/s goodput  \
             replicated '{}' (max {max_rep}x) {:>8.1} i/s goodput",
            base_best.label,
            r_base.goodput,
            rep_best.label,
            r_rep.goodput,
        );
        assert!(max_rep > 1, "{model}: cluster search never replicated a stage");
        assert!(
            r_rep.goodput > r_base.goodput,
            "{model}: replication did not raise simulated goodput \
             ({:.1} vs {:.1} i/s)",
            r_rep.goodput,
            r_base.goodput
        );
        accept_rows.push(obj(vec![
            ("model", Json::from(model)),
            ("nodes", Json::from(16usize)),
            ("offered_rate", Json::from(rate)),
            ("base_label", Json::from(base_best.label.as_str())),
            ("base_goodput", Json::from(r_base.goodput)),
            ("rep_label", Json::from(rep_best.label.as_str())),
            ("rep_max_replicas", Json::from(max_rep)),
            ("rep_goodput", Json::from(r_rep.goodput)),
            ("gain_pct", Json::from(100.0 * (r_rep.goodput - r_base.goodput) / r_base.goodput)),
        ]));
    }

    common::section("cluster goodput scaling (efficientnet_b0, 16/32/64 nodes)");
    let curve_requests = if fast { 100_000 } else { 1_000_000 };
    let gm = zoo::build("efficientnet_b0").unwrap();
    println!("{:>6} {:>14} {:>14} {:>9}", "nodes", "analytic", "sim goodput", "dropped");
    let mut curve_rows = Vec::new();
    for nodes in CLUSTER_SIZES {
        let mut csys = SystemConfig::cluster(nodes);
        csys.search.victory = 20;
        csys.search.max_samples = 200;
        csys.jobs = default_jobs();
        let ex = ExploreRequest::chain().with_cache(Arc::clone(&shared)).run(&gm, &csys);
        let bestc = best_split(&ex);
        // Saturate every preset: each point's goodput reads its capacity.
        let rate = 1.2 * bestc.throughput;
        let r = sim::simulate(
            &Deployment::from_candidate(&bestc, &csys),
            &SimCfg::from_system(&csys),
            &Scenario::steady(curve_requests, rate),
        );
        let tput = bestc.throughput;
        println!("{nodes:>6} {tput:>10.1} i/s {:>10.1} i/s {:>9}", r.goodput, r.dropped);
        curve_rows.push(obj(vec![
            ("nodes", Json::from(nodes)),
            ("analytic_ips", Json::from(bestc.throughput)),
            ("sim_goodput", Json::from(r.goodput)),
            ("dropped", Json::from(r.dropped)),
            ("label", Json::from(bestc.label.as_str())),
        ]));
    }
    // The curve must actually scale: 64 nodes out-serve 16.
    let g16 = curve_rows.first().and_then(|r| r.get("sim_goodput").as_f64()).unwrap();
    let g64 = curve_rows.last().and_then(|r| r.get("sim_goodput").as_f64()).unwrap();
    assert!(g64 > g16, "cluster goodput does not scale: 64 nodes {g64:.1} <= 16 nodes {g16:.1}");

    common::write_bench_json(
        "cluster",
        &obj(vec![
            ("bench", Json::from("serving/cluster")),
            ("fast_mode", Json::from(fast)),
            ("requests", Json::from(cluster_requests)),
            ("acceptance", Json::Arr(accept_rows)),
            ("scaling", Json::Arr(curve_rows)),
        ]),
    );

    // -----------------------------------------------------------------
    // Adaptive serving: static favorite vs live re-partitioning
    // -----------------------------------------------------------------
    common::section("adaptive vs static under the failover preset (node loss on platform 0)");
    let adaptive_requests = if fast { 100_000 } else { 500_000 };
    // Offered load the *surviving* plans can absorb: under the weakest
    // feasible single-platform candidate, so failing over to it is a
    // strict win rather than trading drops for drops.
    let weakest_single = ex
        .candidates
        .iter()
        .filter(|c| c.partitions == 1 && c.feasible())
        .map(|c| c.throughput)
        .fold(f64::INFINITY, f64::min);
    let fallback_exists = ex
        .candidates
        .iter()
        .any(|c| c.partitions == 1 && c.feasible() && c.plan.iter().all(|p| p.platform != 0));
    let arate = 0.8 * weakest_single;
    let failover = Scenario::failover(adaptive_requests, arate);
    let acfg = sys.adaptive;
    let t4 = Instant::now();
    let cmp = sim::compare_adaptive(&ex, &sys, &failover, &cfg, &acfg, default_jobs());
    let adaptive_s = t4.elapsed().as_secs_f64();
    print!("{}", cmp.render());
    println!(
        "static {:.1} i/s vs adaptive {:.1} i/s vs oracle {:.1} i/s (gap {:.1}%), {} in {}",
        cmp.static_report.goodput,
        cmp.adaptive.report.goodput,
        cmp.oracle.report.goodput,
        100.0 * cmp.gap(),
        cmp.adaptive.migrations.len(),
        common::fmt(adaptive_s),
    );
    // Determinism: the three-way comparison must not depend on --jobs.
    let cmp_serial = sim::compare_adaptive(&ex, &sys, &failover, &cfg, &acfg, 1);
    assert_eq!(
        cmp.adaptive.fingerprint(),
        cmp_serial.adaptive.fingerprint(),
        "adaptive run changed under --jobs"
    );
    assert_eq!(
        cmp.oracle.fingerprint(),
        cmp_serial.oracle.fingerprint(),
        "oracle run changed under --jobs"
    );
    // The win is only forced when the favorite actually touches the
    // dying platform and a feasible plan avoiding it exists.
    let exposed = cmp.pool[cmp.static_candidate].platforms.contains(&0);
    if fallback_exists && exposed {
        assert!(
            !cmp.adaptive.migrations.is_empty(),
            "controller never failed over off the dead platform"
        );
        assert!(
            cmp.adaptive.total_migration_bytes > 0 && cmp.adaptive.total_migration_ns > 0,
            "migrations were free"
        );
        assert!(
            cmp.adaptive.report.goodput > cmp.static_report.goodput,
            "adaptive goodput {:.1} did not beat static {:.1} under failover",
            cmp.adaptive.report.goodput,
            cmp.static_report.goodput
        );
    } else {
        println!("note: favorite not exposed to platform 0 loss or no fallback — win assertions skipped");
    }

    common::write_bench_json(
        "adaptive",
        &obj(vec![
            ("bench", Json::from("serving/adaptive")),
            ("fast_mode", Json::from(fast)),
            ("scenario", Json::from("failover")),
            ("requests", Json::from(adaptive_requests)),
            ("offered_rate", Json::from(arate)),
            ("epoch_ms", Json::from(acfg.epoch_s * 1e3)),
            ("hysteresis", Json::from(acfg.hysteresis)),
            ("static_goodput", Json::from(cmp.static_report.goodput)),
            ("adaptive_goodput", Json::from(cmp.adaptive.report.goodput)),
            ("oracle_goodput", Json::from(cmp.oracle.report.goodput)),
            ("oracle_gap", Json::from(cmp.gap())),
            ("migrations", Json::from(cmp.adaptive.migrations.len())),
            ("migration_ms", Json::from(cmp.adaptive.total_migration_ns as f64 / 1e6)),
            ("migration_bytes", Json::from(cmp.adaptive.total_migration_bytes)),
            ("static_dropped", Json::from(cmp.static_report.dropped)),
            ("adaptive_dropped", Json::from(cmp.adaptive.report.dropped)),
            ("wall_s", Json::from(adaptive_s)),
            (
                "adaptive_fingerprint",
                Json::from(format!("{:016x}", cmp.adaptive.fingerprint())),
            ),
            ("oracle_fingerprint", Json::from(format!("{:016x}", cmp.oracle.fingerprint()))),
        ]),
    );

    // -----------------------------------------------------------------
    // Observability overhead: registry live during the 1M-request storm
    // -----------------------------------------------------------------
    common::section(&format!("observability overhead ({requests} request storm, registry live)"));
    // Goodput — like every other report number — is derived purely from
    // virtual time, and the obs layer is write-only from the engine, so
    // an instrumented run must reproduce the bare fingerprint exactly.
    // That equality is the "<5% goodput" acceptance bound with zero
    // slack: the goodput delta is identically 0. Wall-clock cost is
    // recorded for the trajectory but not asserted (CI machines are too
    // noisy to gate on).
    let mut bare_s = f64::INFINITY;
    let mut inst_s = f64::INFINITY;
    let mut storm_spans = 0usize;
    let mut storm_rows = 0usize;
    for _ in 0..3 {
        let tb = Instant::now();
        let rb = sim::simulate(&dep_split, &cfg, &storm);
        bare_s = bare_s.min(tb.elapsed().as_secs_f64());
        // Fresh registry each lap bounds span memory to a single run.
        let reg = Arc::new(Registry::new());
        let ti = Instant::now();
        let ri = sim::simulate_obs(&dep_split, &cfg, &storm, Some(&reg));
        inst_s = inst_s.min(ti.elapsed().as_secs_f64());
        assert_eq!(
            rb.fingerprint(),
            ri.fingerprint(),
            "instrumentation moved the simulation fingerprint"
        );
        storm_spans = reg.span_count();
        storm_rows = reg.snapshot().rows.len();
    }
    let overhead_pct = 100.0 * (inst_s - bare_s) / bare_s;
    println!(
        "bare {} vs instrumented {} (min of 3): wall overhead {overhead_pct:+.1}%, \
         goodput delta 0 (fingerprints equal), {storm_spans} span(s), {storm_rows} metric row(s)",
        common::fmt(bare_s),
        common::fmt(inst_s),
    );

    // Sample trace artifact: a smoke-sized instrumented failover run, so
    // the uploaded trace shows the controller's migration span(s) on the
    // virtual-clock track next to the per-replica service lanes.
    let treg = Arc::new(Registry::new());
    let trace_sc = Scenario::failover(20_000, arate);
    let _ = sim::simulate_adaptive_obs(
        &ex,
        &sys,
        &trace_sc,
        &cfg,
        &acfg,
        ControllerMode::Hysteresis,
        Some(&treg),
    );
    let trace_path = std::path::Path::new("BENCH_obs_trace.json");
    partir::obs::write_trace(&treg, trace_path).expect("writing sample trace");
    let trace_migrations = treg.counter("adaptive.migrations").get();
    println!(
        "wrote {} with {} span(s), {trace_migrations} controller migration span(s)",
        trace_path.display(),
        treg.span_count(),
    );

    common::write_bench_json(
        "obs",
        &obj(vec![
            ("bench", Json::from("serving/obs")),
            ("fast_mode", Json::from(fast)),
            ("requests", Json::from(requests)),
            ("bare_s", Json::from(bare_s)),
            ("instrumented_s", Json::from(inst_s)),
            ("wall_overhead_pct", Json::from(overhead_pct)),
            // Enforced above: fingerprints equal ⇒ goodput delta is 0.
            ("fingerprint_identical", Json::from(true)),
            ("goodput_delta", Json::from(0.0)),
            ("storm_spans", Json::from(storm_spans)),
            ("storm_metric_rows", Json::from(storm_rows)),
            ("trace_spans", Json::from(treg.span_count())),
            ("trace_migrations", Json::from(trace_migrations)),
        ]),
    );

    // -----------------------------------------------------------------
    // Multi-tenant co-scheduling: joint shared-cluster serving vs the
    // best sequential per-tenant plans (acceptance)
    // -----------------------------------------------------------------
    common::section("multi-tenant co-scheduling on the 16-node cluster (acceptance)");
    let mt_requests = if fast { 50_000 } else { 200_000 };
    let mut msys = SystemConfig::cluster(16);
    msys.search.victory = 20;
    msys.search.max_samples = 200;
    msys.jobs = default_jobs();
    let mcfg = SimCfg::from_system(&msys);
    let pair = ["resnet50", "squeezenet1_1"];
    // Solo references: each tenant's best full-cluster plan — also the
    // strongest possible "one model at a time" contender.
    let mut solo: Vec<CandidateMetrics> = Vec::new();
    for model in pair {
        let gm = zoo::build(model).unwrap();
        let sex = ExploreRequest::chain().with_cache(Arc::clone(&shared)).run(&gm, &msys);
        let best = sex
            .candidates
            .iter()
            .filter(|c| c.feasible())
            .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
            .cloned()
            .expect("a feasible solo plan");
        println!("solo {model:<16} '{}' analytic {:.1} i/s", best.label, best.throughput);
        solo.push(best);
    }
    // Offered rates leave the shared cluster room to carry both models
    // at once (~40% of each solo capacity).
    let rates: Vec<f64> = solo.iter().map(|c| 0.4 * c.throughput).collect();
    let set = TenantSet {
        tenants: vec![
            TenantSpec { rate: rates[0], ..TenantSpec::new(pair[0]) },
            TenantSpec { rate: rates[1], priority: 2.0, ..TenantSpec::new(pair[1]) },
        ],
        ..TenantSet::default()
    };

    // Sequential baseline: each tenant's best plan serves its whole
    // stream with the cluster to itself, back to back. Aggregate goodput
    // therefore divides the same total in-SLO completions by the summed
    // occupancy — the cost of not sharing.
    let quiet = Scenario::steady(mt_requests, rates.iter().sum());
    let mut seq_inslo = 0.0f64;
    let mut seq_wall = 0.0f64;
    for (i, best) in solo.iter().enumerate() {
        let traffic = vec![sim::TenantTraffic {
            spec: set.tenants[i].clone(),
            deployment: Deployment::from_candidate(best, &msys),
            requests: mt_requests,
        }];
        let r = sim::simulate_tenants(&traffic, FairnessPolicy::Fifo, &mcfg, &quiet, true);
        seq_inslo += r.tenants[0].goodput * r.wall_s;
        seq_wall += r.wall_s;
    }
    let seq_goodput = seq_inslo / seq_wall;
    println!(
        "sequential baseline: {:.1} i/s aggregate goodput over {:.1}s total occupancy",
        seq_goodput, seq_wall
    );

    // Joint: co-explore both tenants over the shared inventory, then
    // serve every joint candidate through the shared-bank engine.
    let t5 = Instant::now();
    let jex = ExploreRequest::chain().tenants(set.clone()).run_tenants(&msys);
    let joint_explore_s = t5.elapsed().as_secs_f64();
    println!(
        "joint exploration: {} candidates ({} feasible) in {}",
        jex.candidates.len(),
        jex.candidates.iter().filter(|c| c.feasible()).count(),
        common::fmt(joint_explore_s),
    );
    let mt_jobs = default_jobs();
    let ranked = sim::evaluate_tenants(&jex, &msys, mt_requests, &quiet, &mcfg, mt_jobs);
    let ranked_serial = sim::evaluate_tenants(&jex, &msys, mt_requests, &quiet, &mcfg, 1);
    let digest = |r: &[sim::RankedJoint]| -> Vec<(usize, u64)> {
        r.iter().map(|x| (x.index, x.report.fingerprint())).collect()
    };
    assert_eq!(
        digest(&ranked),
        digest(&ranked_serial),
        "multi-tenant ranking changed under --jobs {mt_jobs}"
    );
    let bestj = ranked.first().expect("a joint candidate");
    print!("{}", sim::render_tenant_ranking(&ranked));
    print!("{}", bestj.report.render());
    let joint_gain = 100.0 * (bestj.aggregate_goodput - seq_goodput) / seq_goodput;
    println!(
        "joint '{}' {:.1} i/s vs sequential {:.1} i/s ({joint_gain:+.1}%)",
        bestj.label, bestj.aggregate_goodput, seq_goodput
    );
    assert!(
        bestj.aggregate_goodput > seq_goodput,
        "joint co-scheduling ({:.1} i/s) did not beat sequential per-tenant serving ({:.1} i/s)",
        bestj.aggregate_goodput,
        seq_goodput
    );

    // Fairness sweep over the winning joint candidate.
    let cand = &jex.candidates[bestj.index];
    println!("{:>12} {:>13} {:>10} {:>10}", "policy", "agg goodput", "p99 a", "p99 b");
    let mut fair_rows = Vec::new();
    for policy in [
        FairnessPolicy::Fifo,
        FairnessPolicy::PriorityWeighted,
        FairnessPolicy::TenantRoundRobin,
    ] {
        let traffic: Vec<sim::TenantTraffic> = cand
            .tenants
            .iter()
            .map(|t| sim::TenantTraffic {
                spec: t.spec.clone(),
                deployment: Deployment::from_candidate(&t.metrics, &msys),
                requests: mt_requests,
            })
            .collect();
        let r = sim::simulate_tenants(&traffic, policy, &mcfg, &quiet, true);
        println!(
            "{:>12} {:>9.1} i/s {:>10} {:>10}",
            policy.name(),
            r.aggregate_goodput(),
            common::fmt(r.tenants[0].p99_s),
            common::fmt(r.tenants[1].p99_s),
        );
        fair_rows.push(obj(vec![
            ("policy", Json::from(policy.name())),
            ("aggregate_goodput", Json::from(r.aggregate_goodput())),
            ("p99_a_s", Json::from(r.tenants[0].p99_s)),
            ("p99_b_s", Json::from(r.tenants[1].p99_s)),
            ("fingerprint", Json::from(format!("{:016x}", r.fingerprint()))),
        ]));
    }

    common::write_bench_json(
        "multitenant",
        &obj(vec![
            ("bench", Json::from("serving/multitenant")),
            ("fast_mode", Json::from(fast)),
            ("nodes", Json::from(16usize)),
            ("requests_per_tenant", Json::from(mt_requests)),
            ("tenants", Json::Arr(vec![Json::from(pair[0]), Json::from(pair[1])])),
            ("rates", Json::Arr(rates.iter().map(|&r| Json::from(r)).collect())),
            ("solo_a_label", Json::from(solo[0].label.as_str())),
            ("solo_b_label", Json::from(solo[1].label.as_str())),
            ("sequential_goodput", Json::from(seq_goodput)),
            ("joint_label", Json::from(bestj.label.as_str())),
            ("joint_goodput", Json::from(bestj.aggregate_goodput)),
            ("joint_gain_pct", Json::from(joint_gain)),
            ("joint_explore_s", Json::from(joint_explore_s)),
            ("joint_candidates", Json::from(jex.candidates.len())),
            ("fairness_sweep", Json::Arr(fair_rows)),
        ]),
    );

    // -----------------------------------------------------------------
    // Fault-ensemble robustness: degradation-aware re-ranking of the
    // 16-node cluster's serving set (acceptance)
    // -----------------------------------------------------------------
    common::section("fault-ensemble robustness on the 16-node cluster (acceptance)");
    let rob_requests = if fast { 30_000 } else { 150_000 };
    let rex = ExploreRequest::chain().with_cache(Arc::clone(&shared)).run(&gm, &msys);
    let rcfg = SimCfg::from_system(&msys);
    // Offered load every serving candidate can carry fault-free: with
    // capacity out of the picture, worst-case goodput measures fault
    // exposure — how much of the plan a crash, slowdown, or link fault
    // can take away — which is exactly what the re-ranking is for.
    let serving = rex.serving_candidates();
    let floor = serving
        .iter()
        .map(|&i| rex.candidates[i].throughput)
        .fold(f64::INFINITY, f64::min);
    assert!(floor.is_finite() && floor > 0.0, "serving set has no usable candidate");
    let mut chaos_cfg = msys.chaos;
    chaos_cfg.requests = rob_requests;
    chaos_cfg.rate = 0.8 * floor;
    let mut rob_base = sim::chaos_base_scenario(&rex, &chaos_cfg);
    rob_base.deadline_s = Some(0.1);
    let rob_jobs = default_jobs();
    let t6 = Instant::now();
    let rob = sim::score_robustness(&rex, &msys, &rob_base, &rcfg, &chaos_cfg, rob_jobs);
    let rob_s = t6.elapsed().as_secs_f64();
    print!("{}", rob.render());
    println!(
        "{} serving candidate(s) x {} member(s) ({} fault(s)/member) scored in {}",
        rob.scores.len(),
        chaos_cfg.ensemble,
        chaos_cfg.faults,
        common::fmt(rob_s),
    );
    // Bit-identity: the whole report — every member goodput, TTR, and
    // fingerprint — must survive the worker grid and a rerun.
    let rob_serial = sim::score_robustness(&rex, &msys, &rob_base, &rcfg, &chaos_cfg, 1);
    assert_eq!(
        rob.fingerprint(),
        rob_serial.fingerprint(),
        "robustness report changed under --jobs {rob_jobs}"
    );
    let rob_again = sim::score_robustness(&rex, &msys, &rob_base, &rcfg, &chaos_cfg, rob_jobs);
    assert_eq!(rob.fingerprint(), rob_again.fingerprint(), "robustness report is not rerun-stable");

    let rf = rob.favorite_score().expect("a robust favorite").clone();
    // Throughput favorite: the analytically fastest scored candidate —
    // the plan the plain ranking would ship.
    let tf = rob
        .scores
        .iter()
        .max_by(|a, b| {
            rex.candidates[a.candidate]
                .throughput
                .partial_cmp(&rex.candidates[b.candidate].throughput)
                .unwrap()
                .then(b.candidate.cmp(&a.candidate))
        })
        .unwrap()
        .clone();
    // Dominance holds by construction over every scored plan.
    for s in &rob.scores {
        assert!(
            rf.worst_goodput >= s.worst_goodput,
            "robust favorite '{}' does not dominate '{}' on worst-case goodput",
            rf.label,
            s.label
        );
    }
    // Bounded peak giveback: choosing the robust plan may not cost more
    // than half the throughput favorite's fault-free goodput.
    let giveback_pct =
        100.0 * (tf.baseline_goodput - rf.baseline_goodput) / tf.baseline_goodput.max(1e-9);
    println!(
        "robust '{}' worst {:.1} i/s (baseline {:.1}) vs throughput favorite '{}' worst {:.1} i/s \
         (baseline {:.1}) — peak giveback {giveback_pct:.1}%",
        rf.label,
        rf.worst_goodput,
        rf.baseline_goodput,
        tf.label,
        tf.worst_goodput,
        tf.baseline_goodput,
    );
    assert!(giveback_pct <= 50.0, "robust favorite gives back {giveback_pct:.1}% peak goodput");
    // The strict win is forced when the ensemble actually targets the
    // throughput favorite's platforms while some scored plan escapes
    // every targeted platform (same guard style as the adaptive
    // section: structural, deterministic for the fixed seed).
    let ensemble =
        sim::FaultEnsemble::generate(&rob_base, &chaos_cfg, msys.platforms.len(), rcfg.seed);
    let targeted: std::collections::BTreeSet<usize> = ensemble
        .members
        .iter()
        .flat_map(|m| {
            m.scenario
                .node_loss
                .iter()
                .map(|l| l.platform)
                .chain(m.scenario.slowdowns.iter().map(|s| s.platform))
        })
        .collect();
    let plats = |c: &CandidateMetrics| -> std::collections::BTreeSet<usize> {
        c.plan.iter().map(|p| p.platform).collect()
    };
    let tf_exposed = plats(&rex.candidates[tf.candidate]).iter().any(|p| targeted.contains(p));
    let escape_exists = rob
        .scores
        .iter()
        .any(|s| plats(&rex.candidates[s.candidate]).iter().all(|p| !targeted.contains(p)));
    if tf_exposed && escape_exists {
        assert!(
            rf.worst_goodput > tf.worst_goodput,
            "robust favorite '{}' ({:.1} i/s worst) did not strictly beat the throughput \
             favorite '{}' ({:.1} i/s worst) under the 16-node ensemble",
            rf.label,
            rf.worst_goodput,
            tf.label,
            tf.worst_goodput,
        );
    } else {
        println!(
            "note: ensemble left the throughput favorite unexposed or no plan escaped \
             (exposed: {tf_exposed}, escape: {escape_exists}) — strict-win assertion skipped"
        );
    }

    common::write_bench_json(
        "robustness",
        &obj(vec![
            ("bench", Json::from("serving/robustness")),
            ("fast_mode", Json::from(fast)),
            ("nodes", Json::from(16usize)),
            ("model", Json::from("efficientnet_b0")),
            ("requests", Json::from(rob_requests)),
            ("members", Json::from(chaos_cfg.ensemble)),
            ("faults_per_member", Json::from(chaos_cfg.faults)),
            ("offered_rate", Json::from(chaos_cfg.rate)),
            ("slo_ms", Json::from(100.0)),
            ("candidates_scored", Json::from(rob.scores.len())),
            ("robust_label", Json::from(rf.label.as_str())),
            ("robust_worst_goodput", Json::from(rf.worst_goodput)),
            ("robust_cvar_goodput", Json::from(rf.cvar_goodput)),
            ("robust_baseline_goodput", Json::from(rf.baseline_goodput)),
            ("robust_ttr_epochs", Json::from(rf.ttr_epochs)),
            ("throughput_label", Json::from(tf.label.as_str())),
            ("throughput_worst_goodput", Json::from(tf.worst_goodput)),
            ("throughput_baseline_goodput", Json::from(tf.baseline_goodput)),
            ("peak_giveback_pct", Json::from(giveback_pct)),
            ("strict_win_forced", Json::from(tf_exposed && escape_exists)),
            ("wall_s", Json::from(rob_s)),
            ("fingerprint", Json::from(format!("{:016x}", rob.fingerprint()))),
        ]),
    );
}
