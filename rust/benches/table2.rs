//! Bench: Table II — number of partitions in near-optimal schedules for
//! a 4-accelerator chain (EYR, EYR, SMB, SMB over GbE), all six models,
//! Pareto over latency / energy / link bandwidth.
//!
//!     cargo bench --bench table2
//!
//! Outputs: reports/table2.csv, reports/table2.md.

#[path = "common/mod.rs"]
mod common;

use partir::report::{paper, table2_markdown};
use std::path::Path;
use std::time::Instant;

/// The paper's Table II, for shape comparison.
const PAPER_ROWS: [(&str, [usize; 4]); 6] = [
    ("squeezenet1_1", [1, 5, 7, 1]),
    ("vgg16", [2, 8, 8, 2]),
    ("googlenet", [2, 14, 8, 2]),
    ("resnet50", [2, 10, 10, 5]),
    ("regnet_x_400mf", [2, 6, 12, 13]),
    ("efficientnet_b0", [2, 11, 18, 19]),
];

fn main() -> anyhow::Result<()> {
    common::section("Table II: partition histogram over a 4-platform chain");
    let t0 = Instant::now();
    let rows = paper::table2(
        Path::new("reports"),
        common::fast_mode(),
        partir::util::parallel::default_jobs(),
    )?;
    println!("\nmeasured:\n{}", table2_markdown(&rows));
    println!("paper:");
    for (model, counts) in PAPER_ROWS {
        println!("| {model} | {} | {} | {} | {} |", counts[0], counts[1], counts[2], counts[3]);
    }

    // Shape comparison: fraction of near-optimal schedules that use >= 2
    // partitions, and the multi-partition mass shift for large nets.
    common::section("shape check: multi-partition share of the front");
    println!("{:<18} {:>10} {:>10}", "model", "measured", "paper");
    for (model, counts) in &rows {
        let measured = share(counts);
        let paper = PAPER_ROWS
            .iter()
            .find(|(m, _)| m == model)
            .map(|(_, c)| share(&c.to_vec()))
            .unwrap_or(0.0);
        println!("{model:<18} {measured:>9.0}% {paper:>9.0}%");
    }
    println!("\ntotal table2 regeneration: {}", common::fmt(t0.elapsed().as_secs_f64()));
    Ok(())
}

fn share(counts: &Vec<usize>) -> f64 {
    let total: usize = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    100.0 * counts[1..].iter().sum::<usize>() as f64 / total as f64
}
