//! Shared mini bench harness (criterion substitute for this offline
//! build): warmup + timed iterations, mean/min/MAD reporting, a tabular
//! printer, and the `BENCH_*.json` emitter used to track the perf
//! trajectory across PRs. Each bench target compiles its own copy and
//! uses a subset, hence the allow.
#![allow(dead_code)]

use partir::util::json::Json;
use std::time::Instant;

/// Time `f` over `iters` iterations after `warmup` runs; returns
/// per-iteration seconds (mean, min, mad).
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let mad = partir::util::stats::mad(&samples);
    (mean, min, mad)
}

/// Row printer: `name  mean ± mad  (min)`.
pub fn report(name: &str, mean: f64, min: f64, mad: f64) {
    println!(
        "{name:<44} {:>12} ± {:<10} (min {})",
        fmt(mean),
        fmt(mad),
        fmt(min)
    );
}

pub fn fmt(s: f64) -> String {
    partir::util::units::fmt_time_s(s)
}

/// `PARTIR_BENCH_FAST=1` trims budgets for CI smoke runs.
pub fn fast_mode() -> bool {
    std::env::var("PARTIR_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Write machine-readable bench results to `BENCH_<name>.json` in the
/// current directory (`rust/` under `cargo bench`; CI uploads these as
/// artifacts so the perf trajectory is tracked from PR 2 onward).
pub fn write_bench_json(name: &str, doc: &Json) {
    let path = format!("BENCH_{name}.json");
    match std::fs::write(&path, doc.pretty() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
