//! Bench: Fig 3 — EfficientNet-B0 per-platform memory demand over all
//! partitioning points on two 16-bit platforms, plus timing of the
//! Definition-3 estimator itself.
//!
//!     cargo bench --bench fig3

#[path = "common/mod.rs"]
mod common;

use partir::graph::topo::{topo_sort, TieBreak};
use partir::memory;
use partir::report::paper;
use partir::zoo;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    common::section("Fig 3: EfficientNet-B0 memory vs partition point (two 16-bit platforms)");
    paper::fig3(Path::new("reports"))?;

    // The paper's reading: memory grows with later partitioning; knees
    // near Conv_56 / Conv_79. Print the series' key points.
    let g = zoo::efficientnet_b0(1000);
    let order = topo_sort(&g, TieBreak::Deterministic);
    let total = g.len();
    println!("\n{:<12} {:>10} {:>10}", "cut", "mem A", "mem B");
    for frac in [0.1, 0.3, 0.5, 0.7, 0.9] {
        let pos = ((total as f64 * frac) as usize).min(total - 2);
        let ma = memory::segment_memory_bytes(&g, &order, 0..pos + 1, 16);
        let mb = memory::segment_memory_bytes(&g, &order, pos + 1..total, 16);
        println!(
            "{:<12} {:>10} {:>10}",
            g.node(order[pos]).name,
            partir::util::units::fmt_bytes(ma),
            partir::util::units::fmt_bytes(mb)
        );
    }

    common::section("Definition-3 estimator micro-bench");
    for name in ["squeezenet1_1", "resnet50", "efficientnet_b0"] {
        let g = zoo::build(name).unwrap();
        let order = topo_sort(&g, TieBreak::Deterministic);
        let mid = g.len() / 2;
        let (mean, min, mad) = common::bench(3, if common::fast_mode() { 20 } else { 200 }, || {
            std::hint::black_box(memory::segment_memory_bytes(
                &g,
                &order,
                0..mid,
                16,
            ));
        });
        common::report(&format!("segment_memory_bytes({name})"), mean, min, mad);
    }
    Ok(())
}
