//! Bench: incremental DAG-plan evaluation (stage-granular cost cache,
//! allocation-free eval scratch, bound-pruned genome scoring).
//!
//! Three sections:
//! 1. DAG sweep throughput — enumerate two-platform convex DAG cuts
//!    (`graph::partition::dag_cuts`) and score them three ways: the
//!    preserved pre-cache reference path
//!    (`explorer::reference::DagReference`, global `Mutex<HashMap>`
//!    memo + per-genome allocations), the incremental path with a cold
//!    stage cache, and the incremental path warm + bound-pruned. All
//!    three must produce a **bit-identical Pareto front**; acceptance
//!    is ≥ 3× genomes/second for warm-pruned vs the reference.
//! 2. `explore_dag` serial vs `--jobs N` — identical fronts, wall-clock
//!    speedup of the full (chain + assignment-GA) exploration.
//! 3. machine-readable results in `BENCH_dag.json`.
//!
//!     cargo bench --bench dag_explore

#[path = "common/mod.rs"]
mod common;

use partir::config::SystemConfig;
use partir::explorer::reference::DagReference;
use partir::explorer::{sweep_dag_front, CandidateMetrics, ExploreRequest, PlanEvaluator};
use partir::graph::partition::dag_cuts;
use partir::util::json::{obj, Json};
use partir::zoo;
use std::time::Instant;

fn bench_sys(fast: bool) -> SystemConfig {
    let mut sys = SystemConfig::paper_two_platform();
    if fast {
        sys.search.victory = 15;
        sys.search.max_samples = 150;
    } else {
        sys.search.victory = 50;
        sys.search.max_samples = 1000;
    }
    sys.jobs = 1;
    sys
}

fn assert_fronts_identical(a: &[CandidateMetrics], b: &[CandidateMetrics], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: front sizes diverged");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.label, y.label, "{what}");
        assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits(), "{what}: {}", x.label);
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{what}: {}", x.label);
        assert_eq!(x.throughput.to_bits(), y.throughput.to_bits(), "{what}: {}", x.label);
        assert_eq!(x.top1.to_bits(), y.top1.to_bits(), "{what}: {}", x.label);
        assert_eq!(x.link_bytes, y.link_bytes, "{what}: {}", x.label);
        assert_eq!(x.memory_bytes, y.memory_bytes, "{what}: {}", x.label);
    }
}

/// Pareto front of the reference evaluator over the whole sweep (its
/// "current evaluator" behavior: every genome fully surfaced, no
/// pruning, fresh memo per run).
fn reference_front(
    ev: &PlanEvaluator,
    assigns: &[Vec<usize>],
    metrics: &[partir::config::Metric],
) -> Vec<CandidateMetrics> {
    let reference = DagReference::new(ev);
    let cands: Vec<CandidateMetrics> =
        assigns.iter().map(|a| reference.evaluate_dag(a)).collect();
    partir::explorer::exhaustive_pareto(&cands, metrics)
        .into_iter()
        .map(|i| cands[i].clone())
        .collect()
}

fn main() {
    let fast = common::fast_mode();
    let iters = if fast { 3 } else { 7 };
    let sys = bench_sys(fast);
    let cap = if fast { 120 } else { 400 };
    let models: &[&str] = if fast {
        &["squeezenet1_1"]
    } else {
        &["squeezenet1_1", "googlenet", "resnet50"]
    };

    common::section(&format!(
        "DAG sweep: reference vs incremental (cap {cap} genomes, victory={}, max_samples={})",
        sys.search.victory, sys.search.max_samples
    ));
    println!(
        "{:<16} {:>8} {:>10} {:>10} {:>10} {:>8} {:>10} {:>10} {:>9}",
        "model", "genomes", "ref g/s", "cold g/s", "warm g/s", "pruned", "hits", "misses", "speedup"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut ln_speedups: Vec<f64> = Vec::new();
    for model in models {
        let g = zoo::build(model).unwrap();
        let ev = PlanEvaluator::new(&g, &sys);
        let assigns = dag_cuts(&g, cap);
        let n = assigns.len();

        // Correctness first: all three paths agree on the front.
        let front_ref = reference_front(&ev, &assigns, &sys.pareto_metrics);
        ev.clear_stage_cache();
        let (front_cold, _) = sweep_dag_front(&ev, &assigns, false);
        let (front_warm, stats) = sweep_dag_front(&ev, &assigns, true);
        assert_fronts_identical(&front_ref, &front_cold, &format!("{model}: ref vs cold"));
        assert_fronts_identical(&front_cold, &front_warm, &format!("{model}: cold vs warm+pruned"));
        assert!(
            stats.evaluated + stats.pruned == n,
            "{model}: sweep lost genomes ({} + {} != {n})",
            stats.evaluated,
            stats.pruned
        );

        // Reference throughput: fresh memo per run, exactly one run's
        // worth of work each iteration.
        let mut ref_min = f64::INFINITY;
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(reference_front(&ev, &assigns, &sys.pareto_metrics));
            ref_min = ref_min.min(t.elapsed().as_secs_f64());
        }
        // Cold incremental: stage cache dropped before every run.
        let mut cold_min = f64::INFINITY;
        for _ in 0..iters {
            ev.clear_stage_cache();
            let t = Instant::now();
            std::hint::black_box(sweep_dag_front(&ev, &assigns, false));
            cold_min = cold_min.min(t.elapsed().as_secs_f64());
        }
        // Warm incremental + bound prune: the NSGA-II steady state.
        ev.clear_stage_cache();
        let _ = sweep_dag_front(&ev, &assigns, true); // warm the cache
        let mut warm_min = f64::INFINITY;
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(sweep_dag_front(&ev, &assigns, true));
            warm_min = warm_min.min(t.elapsed().as_secs_f64());
        }
        let (hits, misses, entries) = ev.stage_cache_stats();
        let (ref_gps, cold_gps, warm_gps) = (
            n as f64 / ref_min.max(1e-12),
            n as f64 / cold_min.max(1e-12),
            n as f64 / warm_min.max(1e-12),
        );
        let speedup = ref_min / warm_min.max(1e-12);
        ln_speedups.push(speedup.max(1e-12).ln());
        println!(
            "{:<16} {:>8} {:>10.0} {:>10.0} {:>10.0} {:>8} {:>10} {:>10} {:>8.2}x",
            model, n, ref_gps, cold_gps, warm_gps, stats.pruned, hits, misses, speedup
        );
        rows.push(obj(vec![
            ("model", Json::from(*model)),
            ("genomes", Json::from(n)),
            ("ref_s", Json::from(ref_min)),
            ("cold_s", Json::from(cold_min)),
            ("warm_s", Json::from(warm_min)),
            ("ref_genomes_per_s", Json::from(ref_gps)),
            ("cold_genomes_per_s", Json::from(cold_gps)),
            ("warm_genomes_per_s", Json::from(warm_gps)),
            ("pruned", Json::from(stats.pruned)),
            ("evaluated", Json::from(stats.evaluated)),
            ("cache_hits", Json::from(hits)),
            ("cache_misses", Json::from(misses)),
            ("cache_entries", Json::from(entries)),
            ("front_size", Json::from(front_ref.len())),
            ("speedup_vs_reference", Json::from(speedup)),
            ("identical_front", Json::from(true)),
        ]));
        assert!(
            speedup >= 3.0,
            "{model}: warm incremental sweep only {speedup:.2}x the reference (need >= 3x)"
        );
    }
    let geomean =
        (ln_speedups.iter().sum::<f64>() / ln_speedups.len().max(1) as f64).exp();
    println!(
        "\nsweep speedup geomean: {geomean:.2}x \
         (acceptance: >= 3x genomes/s at a bit-identical Pareto front)"
    );

    common::section("explore_dag: serial vs parallel (identical fronts)");
    let jobs = partir::util::parallel::default_jobs().clamp(2, 4);
    println!("{:<16} {:>12} {:>12} {:>9}", "model", "serial", "jobs", "speedup");
    let mut explore_rows: Vec<Json> = Vec::new();
    for model in models {
        let g = zoo::build(model).unwrap();
        let mut serial_sys = bench_sys(fast);
        serial_sys.jobs = 1;
        let mut par_sys = bench_sys(fast);
        par_sys.jobs = jobs;
        let t = Instant::now();
        let a = ExploreRequest::dag().run(&g, &serial_sys);
        let serial_s = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let b = ExploreRequest::dag().run(&g, &par_sys);
        let par_s = t.elapsed().as_secs_f64();
        assert_eq!(a.pareto, b.pareto, "{model}: parallel front diverged");
        assert_eq!(a.favorite, b.favorite, "{model}: favorite diverged");
        assert_eq!(a.candidates.len(), b.candidates.len(), "{model}");
        for (x, y) in a.candidates.iter().zip(&b.candidates) {
            assert_eq!(x.label, y.label, "{model}");
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits(), "{model}: {}", x.label);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{model}: {}", x.label);
        }
        let speedup = serial_s / par_s.max(1e-12);
        println!(
            "{:<16} {:>12} {:>12} {:>8.2}x",
            model,
            common::fmt(serial_s),
            common::fmt(par_s),
            speedup
        );
        explore_rows.push(obj(vec![
            ("model", Json::from(*model)),
            ("serial_s", Json::from(serial_s)),
            ("parallel_s", Json::from(par_s)),
            ("jobs", Json::from(jobs)),
            ("speedup", Json::from(speedup)),
            ("identical_front", Json::from(true)),
        ]));
    }

    common::write_bench_json(
        "dag",
        &obj(vec![
            ("bench", Json::from("dag_explore")),
            ("fast_mode", Json::from(fast)),
            ("cap", Json::from(cap)),
            ("sweep", Json::Arr(rows)),
            ("sweep_speedup_geomean", Json::from(geomean)),
            ("explore", Json::Arr(explore_rows)),
            ("identical_fronts", Json::from(true)),
        ]),
    );
}
