//! Bench: the Timeloop-like mapping search (the DSE's hot path) plus the
//! victory-condition ablation called out in DESIGN.md — how search budget
//! trades mapping quality (EDP) against wall time, mirroring the paper's
//! Timeloop setting of "linear-pruned search, victory condition 100".
//!
//!     cargo bench --bench mapper

#[path = "common/mod.rs"]
mod common;

use partir::hw::{mapper, presets, ConvWorkload, SearchCfg};
use partir::zoo;

fn workloads() -> Vec<(String, ConvWorkload)> {
    let mut out = Vec::new();
    for (model, layer) in [
        ("resnet50", "Conv_0"),   // 7x7 stem, large spatial
        ("resnet50", "Conv_10"),  // 1x1 bottleneck
        ("vgg16", "Conv_5"),      // 3x3 256-ch, reuse-rich
        ("efficientnet_b0", "Conv_1"), // depthwise
        ("resnet50", "Gemm_0"),   // FC, memory-bound
    ] {
        let g = zoo::build(model).unwrap();
        let node = g.by_name(layer).unwrap();
        out.push((
            format!("{model}/{layer}"),
            ConvWorkload::from_node(&g, node).unwrap(),
        ));
    }
    out
}

fn main() {
    let iters = if common::fast_mode() { 3 } else { 15 };
    common::section("map_layer search time (victory=100, max_samples=4000)");
    let cfg = SearchCfg::default();
    for (name, wl) in workloads() {
        for acc in [presets::eyeriss_like(), presets::simba_like()] {
            let (mean, min, mad) = common::bench(1, iters, || {
                std::hint::black_box(mapper::map_layer(&acc, &wl, &cfg));
            });
            common::report(&format!("{name} on {}", acc.name), mean, min, mad);
        }
    }

    common::section("victory-condition ablation (EYR, vgg16/Conv_5)");
    let g = zoo::vgg16(1000);
    let wl = ConvWorkload::from_node(&g, g.by_name("Conv_5").unwrap()).unwrap();
    let acc = presets::eyeriss_like();
    println!(
        "{:>8} {:>12} {:>12} {:>14} {:>10}",
        "victory", "latency", "energy", "EDP", "time"
    );
    let mut base_edp = None;
    for victory in [10usize, 25, 50, 100, 200, 400] {
        let cfg = SearchCfg { victory, max_samples: 20_000, ..Default::default() };
        let t = std::time::Instant::now();
        let cost = mapper::map_layer(&acc, &wl, &cfg);
        let dt = t.elapsed().as_secs_f64();
        let edp = cost.latency_s * cost.energy_j;
        let rel = base_edp.get_or_insert(edp);
        println!(
            "{victory:>8} {:>12} {:>12} {:>13.3}x {:>10}",
            common::fmt(cost.latency_s),
            partir::util::units::fmt_energy_j(cost.energy_j),
            edp / *rel,
            common::fmt(dt)
        );
    }
    println!("(EDP relative to victory=10; diminishing returns justify the paper's 100)");
}
