//! Bench: the Timeloop-like mapping search (the DSE's hot path).
//!
//! Three sections:
//! 1. kernel throughput — the bound-pruned zero-allocation kernel
//!    (`mapper::map_layer`) against the straight-line reference kernel
//!    (`mapper::reference::map_layer`), asserting bit-identical chosen
//!    mappings and reporting samples/s per workload (acceptance: ≥ 3×
//!    single-thread speedup at identical mappings);
//! 2. the victory-condition ablation called out in DESIGN.md — how
//!    search budget trades mapping quality (EDP) against wall time,
//!    mirroring the paper's "linear-pruned search, victory condition 100";
//! 3. machine-readable results in `BENCH_mapper.json`.
//!
//!     cargo bench --bench mapper

#[path = "common/mod.rs"]
mod common;

use partir::hw::{mapper, presets, ConvWorkload, SearchCfg};
use partir::util::json::{obj, Json};
use partir::zoo;

fn workloads() -> Vec<(String, ConvWorkload)> {
    let mut out = Vec::new();
    for (model, layer) in [
        ("resnet50", "Conv_0"),   // 7x7 stem, large spatial
        ("resnet50", "Conv_10"),  // 1x1 bottleneck
        ("vgg16", "Conv_5"),      // 3x3 256-ch, reuse-rich
        ("efficientnet_b0", "Conv_1"), // depthwise
        ("resnet50", "Gemm_0"),   // FC, memory-bound
    ] {
        let g = zoo::build(model).unwrap();
        let node = g.by_name(layer).unwrap();
        out.push((
            format!("{model}/{layer}"),
            ConvWorkload::from_node(&g, node).unwrap(),
        ));
    }
    out
}

fn main() {
    let fast = common::fast_mode();
    let iters = if fast { 3 } else { 15 };
    let cfg = if fast {
        SearchCfg { victory: 25, max_samples: 500, ..Default::default() }
    } else {
        SearchCfg::default()
    };

    common::section(&format!(
        "kernel throughput: bound-pruned zero-alloc vs straight-line reference \
         (victory={}, max_samples={})",
        cfg.victory, cfg.max_samples
    ));
    println!(
        "{:<36} {:>8} {:>8} {:>12} {:>12} {:>9}",
        "workload", "samples", "pruned", "ref smp/s", "fast smp/s", "speedup"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut ln_speedups: Vec<f64> = Vec::new();
    for (name, wl) in workloads() {
        for acc in [presets::eyeriss_like(), presets::simba_like()] {
            // Equivalence first: the speedup below is only meaningful at
            // identical answers.
            let (fast_cost, stats) = mapper::map_layer_with_stats(&acc, &wl, &cfg);
            let (ref_cost, ref_stats) = mapper::reference::map_layer_with_stats(&acc, &wl, &cfg);
            assert_eq!(
                fast_cost.latency_s.to_bits(),
                ref_cost.latency_s.to_bits(),
                "{name} on {}: latency diverged",
                acc.name
            );
            assert_eq!(
                fast_cost.energy_j.to_bits(),
                ref_cost.energy_j.to_bits(),
                "{name} on {}: energy diverged",
                acc.name
            );
            assert_eq!(
                fast_cost.mapping_desc, ref_cost.mapping_desc,
                "{name} on {}: chosen mapping diverged",
                acc.name
            );
            assert_eq!(stats.samples, ref_stats.samples, "{name}: RNG streams diverged");

            let (_, ref_min, _) = common::bench(1, iters, || {
                std::hint::black_box(mapper::reference::map_layer(&acc, &wl, &cfg));
            });
            let (_, fast_min, _) = common::bench(1, iters, || {
                std::hint::black_box(mapper::map_layer(&acc, &wl, &cfg));
            });
            let samples = stats.samples as f64;
            let ref_sps = samples / ref_min.max(1e-12);
            let fast_sps = samples / fast_min.max(1e-12);
            let speedup = ref_min / fast_min.max(1e-12);
            ln_speedups.push(speedup.max(1e-12).ln());
            println!(
                "{:<36} {:>8} {:>8} {:>12.0} {:>12.0} {:>8.2}x",
                format!("{name} on {}", acc.name),
                stats.samples,
                stats.pruned,
                ref_sps,
                fast_sps,
                speedup
            );
            rows.push(obj(vec![
                ("workload", Json::from(name.clone())),
                ("acc", Json::from(acc.name.clone())),
                ("samples", Json::from(stats.samples)),
                ("pruned", Json::from(stats.pruned)),
                ("ref_s", Json::from(ref_min)),
                ("fast_s", Json::from(fast_min)),
                ("ref_samples_per_s", Json::from(ref_sps)),
                ("fast_samples_per_s", Json::from(fast_sps)),
                ("speedup", Json::from(speedup)),
                ("identical_mapping", Json::from(true)),
            ]));
        }
    }
    let geomean =
        (ln_speedups.iter().sum::<f64>() / ln_speedups.len().max(1) as f64).exp();
    println!(
        "\nkernel speedup geomean: {geomean:.2}x \
         (acceptance: >= 3x single-thread at identical chosen mappings)"
    );

    common::section("victory-condition ablation (EYR, vgg16/Conv_5)");
    let g = zoo::vgg16(1000);
    let wl = ConvWorkload::from_node(&g, g.by_name("Conv_5").unwrap()).unwrap();
    let acc = presets::eyeriss_like();
    println!(
        "{:>8} {:>12} {:>12} {:>14} {:>10}",
        "victory", "latency", "energy", "EDP", "time"
    );
    let mut base_edp = None;
    let mut ablation: Vec<Json> = Vec::new();
    for victory in [10usize, 25, 50, 100, 200, 400] {
        let cfg = SearchCfg { victory, max_samples: 20_000, ..Default::default() };
        let t = std::time::Instant::now();
        let cost = mapper::map_layer(&acc, &wl, &cfg);
        let dt = t.elapsed().as_secs_f64();
        let edp = cost.latency_s * cost.energy_j;
        let rel = base_edp.get_or_insert(edp);
        println!(
            "{victory:>8} {:>12} {:>12} {:>13.3}x {:>10}",
            common::fmt(cost.latency_s),
            partir::util::units::fmt_energy_j(cost.energy_j),
            edp / *rel,
            common::fmt(dt)
        );
        ablation.push(obj(vec![
            ("victory", Json::from(victory)),
            ("edp_rel", Json::from(edp / *rel)),
            ("time_s", Json::from(dt)),
        ]));
    }
    println!("(EDP relative to victory=10; diminishing returns justify the paper's 100)");

    common::write_bench_json(
        "mapper",
        &obj(vec![
            ("bench", Json::from("mapper")),
            ("fast_mode", Json::from(fast)),
            ("victory", Json::from(cfg.victory)),
            ("max_samples", Json::from(cfg.max_samples)),
            ("kernels", Json::Arr(rows)),
            ("speedup_geomean", Json::from(geomean)),
            ("identical_mappings", Json::from(true)),
            ("victory_ablation", Json::Arr(ablation)),
        ]),
    );
}
