//! Bench: regenerate Fig 2 (a–f) — the per-model two-platform
//! partitioning series — and report exploration wall time per model
//! plus the paper's headline throughput gains.
//!
//!     cargo bench --bench fig2
//!
//! Outputs: reports/fig2*.csv (same files as `partir report`).

#[path = "common/mod.rs"]
mod common;

use partir::report::paper;
use std::path::Path;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let fast = common::fast_mode();
    common::section("Fig 2: two-platform exploration per model (EYR -> GbE -> SMB)");
    let t0 = Instant::now();
    let gains = paper::fig2(Path::new("reports"), fast, partir::util::parallel::default_jobs())?;
    println!("\ntotal fig2 regeneration: {}", common::fmt(t0.elapsed().as_secs_f64()));

    common::section("headline: pipelined throughput gain over best single platform");
    println!("{:<18} {:>8}  paper reference", "model", "gain");
    for (model, gain) in &gains {
        let paper_ref = match model.as_str() {
            "resnet50" => "+29% (Fig 2b, ReLu_11)",
            "efficientnet_b0" => "+47.5% (Fig 2e, Conv_45)",
            _ => "-",
        };
        println!("{model:<18} {gain:>+7.1}%  {paper_ref}");
    }
    Ok(())
}
