//! Bench: the L3 pipeline coordinator — batching sweep, backpressure,
//! link-simulation overhead, and (when artifacts exist) the real
//! two-stage AOT pipeline measured against its Definition-4 prediction.
//!
//!     cargo bench --bench pipeline

#[path = "common/mod.rs"]
mod common;

use partir::coordinator::{run_pipeline, BatchPolicy, PipelineCfg, StageComputeSpec, StageSpec};
use partir::runtime::Manifest;
use std::path::Path;
use std::time::Duration;

fn sim_stage(name: &str, per_item_us: u64) -> StageSpec {
    StageSpec {
        name: name.into(),
        compute: StageComputeSpec::Simulated {
            base: Duration::from_micros(100),
            per_item: Duration::from_micros(per_item_us),
            out_elems: 64,
            fail_every: None,
        },
        out_bytes_per_item: 2048,
    }
}

fn main() {
    let n = if common::fast_mode() { 64 } else { 256 };
    common::section(format!("batch-size sweep, 2 simulated stages, {n} requests").as_str());
    println!("{:>6} {:>14} {:>12} {:>12}", "batch", "throughput", "p50", "p99");
    for batch in [1usize, 2, 4, 8, 16] {
        let cfg = PipelineCfg {
            batch: BatchPolicy::new(batch, Duration::from_micros(500)),
            simulate_link: true,
            ..Default::default()
        };
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0; 64]).collect();
        let r = run_pipeline(vec![sim_stage("a", 300), sim_stage("b", 300)], &cfg, inputs);
        println!(
            "{batch:>6} {:>10.1} i/s {:>12} {:>12}",
            r.throughput(),
            common::fmt(r.latency_percentile(50.0)),
            common::fmt(r.latency_percentile(99.0))
        );
    }

    common::section("queue-depth (backpressure) sweep");
    println!("{:>6} {:>14} {:>12}", "depth", "throughput", "p99");
    for depth in [1usize, 4, 16, 64] {
        let cfg = PipelineCfg {
            batch: BatchPolicy::new(8, Duration::from_micros(500)),
            queue_depth: depth,
            simulate_link: true,
            ..Default::default()
        };
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0; 64]).collect();
        let r = run_pipeline(vec![sim_stage("a", 200), sim_stage("b", 400)], &cfg, inputs);
        println!(
            "{depth:>6} {:>10.1} i/s {:>12}",
            r.throughput(),
            common::fmt(r.latency_percentile(99.0))
        );
    }

    // Real artifacts, if built.
    let dir = Path::new("artifacts");
    let Ok(m) = Manifest::load(dir) else {
        println!("\n(artifacts not built; skipping the real AOT pipeline — run `make artifacts`)");
        return;
    };
    common::section("real AOT pipeline (boundary sweep, quantized 16b/8b)");
    let ts = m.load_testset().unwrap();
    let reqs = if common::fast_mode() { 32 } else { 128 };
    let inputs: Vec<Vec<f32>> = (0..reqs).map(|i| ts.image(i % ts.count).to_vec()).collect();
    println!(
        "{:>9} {:>14} {:>12} {:>12} {:>10}",
        "boundary", "throughput", "p50", "p99", "fill A"
    );
    for bd in 1..=3usize {
        let mid: usize = m.boundaries[&bd].shape.iter().product();
        let pick = |role: &str, bits: Option<u32>| {
            vec![
                m.find(role, bits, Some(bd), 1).unwrap().clone(),
                m.find(role, bits, Some(bd), 8).unwrap().clone(),
            ]
        };
        let stages = vec![
            StageSpec {
                name: "A".into(),
                compute: StageComputeSpec::Artifacts {
                    dir: dir.to_path_buf(),
                    metas: pick("stageA", Some(16)),
                },
                out_bytes_per_item: (mid * 2) as u64,
            },
            StageSpec {
                name: "B".into(),
                compute: StageComputeSpec::Artifacts {
                    dir: dir.to_path_buf(),
                    metas: pick("stageB", Some(8)),
                },
                out_bytes_per_item: 0,
            },
        ];
        let cfg = PipelineCfg {
            batch: BatchPolicy::new(8, Duration::from_millis(1)),
            ..Default::default()
        };
        let r = run_pipeline(stages, &cfg, inputs.clone());
        println!(
            "{bd:>9} {:>10.1} i/s {:>12} {:>12} {:>10.2}",
            r.throughput(),
            common::fmt(r.latency_percentile(50.0)),
            common::fmt(r.latency_percentile(99.0)),
            r.stages[0].mean_batch()
        );
    }
}
