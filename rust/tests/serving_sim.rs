//! Integration tests for the discrete-event serving simulator:
//!
//! 1. cross-validation against the wall-clock coordinator (same
//!    deployment + same arrival pattern ⇒ throughput/latency agree
//!    within tolerance — modelling deltas are documented in DESIGN.md's
//!    "Serving simulator" section);
//! 2. the determinism gate: `evaluate_front` is bit-identical for every
//!    `jobs` value (CI greps for `determinism` in this suite — do not
//!    rename without updating .github/workflows/ci.yml);
//! 3. the paper's qualitative serving claim, reproduced on simulated
//!    numbers: the best partitioned deployment out-serves the best
//!    single-platform deployment.

use partir::config::SystemConfig;
use partir::coordinator::{
    run_pipeline, BatchPolicy, PipelineCfg, StageComputeSpec, StageSpec,
};
use partir::explorer::ExploreRequest;
use partir::sim::{self, Deployment, Scenario, SimCfg};
use partir::zoo;
use std::time::Duration;

fn quick_sys() -> SystemConfig {
    let mut sys = SystemConfig::paper_two_platform();
    sys.search.victory = 15;
    sys.search.max_samples = 150;
    sys
}

/// Satellite: same deployment + same arrival list through both
/// runtimes. The coordinator is wall-clock (thread wakeups, channel
/// overhead), so the tolerance is deliberately loose; what must hold is
/// that the simulator predicts the same serving regime, not the same
/// microsecond.
#[test]
fn sim_cross_validates_wallclock_coordinator() {
    let n = 120usize;
    let per_item = Duration::from_micros(2000);
    let batch = BatchPolicy::new(4, Duration::from_micros(500));
    let out_bytes = 2048u64;

    // Wall-clock run: queue deep enough that the feeder never blocks,
    // so every request is effectively submitted at t = 0 — the
    // closed-loop pattern the replay scenario mirrors below.
    let stages = vec![
        StageSpec {
            name: "a".into(),
            compute: StageComputeSpec::Simulated {
                base: Duration::ZERO,
                per_item,
                out_elems: 8,
                fail_every: None,
            },
            out_bytes_per_item: out_bytes,
        },
        StageSpec {
            name: "b".into(),
            compute: StageComputeSpec::Simulated {
                base: Duration::ZERO,
                per_item,
                out_elems: 4,
                fail_every: None,
            },
            out_bytes_per_item: 0,
        },
    ];
    let cfg = PipelineCfg { batch, queue_depth: n, simulate_link: true, ..Default::default() };
    let inputs: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0; 8]).collect();
    let wall = run_pipeline(stages, &cfg, inputs);
    assert_eq!(wall.completed(), n);

    // Virtual-clock run of the same deployment and arrival pattern.
    let dep = Deployment::synthetic("xval", &[2e-3, 2e-3], out_bytes);
    let sim_cfg = SimCfg { batch, queue_depth: n, seed: 0, ..Default::default() };
    let r = sim::simulate(&dep, &sim_cfg, &Scenario::replay(vec![0.0; n]));
    assert_eq!(r.pipeline.completed(), n);
    assert_eq!(r.dropped, 0);

    // The sim is the ideal (no scheduler overhead) side, so it can only
    // be *faster* than the wall clock; on a loaded CI runner sleep
    // overshoot inflates the wall-clock side, so the upper bound must
    // stay loose — the regime, not the microsecond, is what's checked.
    let (tw, ts) = (wall.throughput(), r.throughput());
    let ratio = ts / tw;
    assert!(
        (0.6..=2.5).contains(&ratio),
        "throughput diverges: sim {ts:.1}/s vs wall-clock {tw:.1}/s (ratio {ratio:.2})"
    );
    let (lw, ls) = (
        wall.latency_summary().mean(),
        r.pipeline.latency_summary().mean(),
    );
    let lat_ratio = ls / lw;
    assert!(
        (0.3..=1.6).contains(&lat_ratio),
        "mean latency diverges: sim {ls:.4}s vs wall-clock {lw:.4}s (ratio {lat_ratio:.2})"
    );
    // Both runtimes batch identically (shared BatchPolicy): mean fill
    // of the bottleneck stage must agree closely.
    let fill_ratio = r.pipeline.stages[0].mean_batch() / wall.stages[0].mean_batch();
    assert!(
        (0.7..=1.3).contains(&fill_ratio),
        "batch fill diverges: sim {:.2} vs wall-clock {:.2}",
        r.pipeline.stages[0].mean_batch(),
        wall.stages[0].mean_batch()
    );
}

/// The determinism acceptance gate: exploration → evaluate_front is
/// bit-identical across worker counts AND across repeated runs.
#[test]
fn sim_determinism_bit_identical_across_jobs() {
    let g = zoo::tiny_cnn(10);
    let sys = quick_sys();
    let ex = ExploreRequest::chain().run(&g, &sys);
    let single_best = ex
        .candidates
        .iter()
        .filter(|c| c.partitions == 1)
        .map(|c| c.throughput)
        .fold(0.0f64, f64::max);
    assert!(single_best > 0.0);
    let mut scenario = Scenario::diurnal(20_000, 0.5 * single_best, 2.0 * single_best);
    scenario.deadline_s = Some(0.25);
    let cfg = SimCfg::from_system(&sys);

    let serial = sim::evaluate_front(&ex, &sys, &scenario, &cfg, 1);
    for jobs in [2usize, 4, 8] {
        let par = sim::evaluate_front(&ex, &sys, &scenario, &cfg, jobs);
        assert_eq!(serial, par, "jobs={jobs} changed the ranking");
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.fingerprint, b.fingerprint, "jobs={jobs}");
            assert_eq!(a.goodput.to_bits(), b.goodput.to_bits(), "jobs={jobs}");
            assert_eq!(a.p99_s.to_bits(), b.p99_s.to_bits(), "jobs={jobs}");
        }
    }
    // Repeated serial runs are also bit-identical (no hidden state).
    let again = sim::evaluate_front(&ex, &sys, &scenario, &cfg, 1);
    assert_eq!(serial, again);
}

/// Acceptance: the paper's qualitative result on *simulated* serving —
/// a partitioned EfficientNet/ResNet-class deployment sustains higher
/// steady-state throughput than the best single platform once traffic
/// exceeds what one platform can serve.
#[test]
fn simulated_partitioned_throughput_beats_single_platform() {
    let g = zoo::resnet50(1000);
    let sys = quick_sys();
    let ex = ExploreRequest::chain().run(&g, &sys);
    let single_best = ex
        .candidates
        .iter()
        .filter(|c| c.partitions == 1 && c.feasible())
        .map(|c| c.throughput)
        .fold(0.0f64, f64::max);
    assert!(single_best > 0.0);
    // Offer 1.5x the best single platform's analytic capacity.
    let scenario = Scenario::steady(30_000, 1.5 * single_best);
    let cfg = SimCfg::from_system(&sys);
    let ranked = sim::evaluate_front(&ex, &sys, &scenario, &cfg, 4);
    assert!(ranked.iter().any(|r| r.partitions == 1), "no single-platform baseline");
    assert!(ranked.iter().any(|r| r.partitions >= 2), "no partitioned candidate");
    let (label, gain) = sim::best_gain_over_single(&ranked).unwrap();
    assert!(
        gain > 0.0,
        "partitioned deployment '{label}' does not beat single platform (gain {gain:.1}%)"
    );
}
