//! Acceptance gate for cost-cache persistence (PR 2 tentpole): a cache
//! round-tripped through `--cache-dir` must make the second exploration
//! run perform **zero** mapper searches while producing bit-identical
//! fronts, and stale/corrupt cache files must be ignored, never fatal.

use partir::config::SystemConfig;
use partir::explorer::ExploreRequest;
use partir::hw::{CacheLoad, CostCache, SearchCfg, COST_CACHE_FILE};
use partir::zoo;
use std::path::PathBuf;
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("partir_cache_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn quick_sys() -> SystemConfig {
    let mut sys = SystemConfig::paper_two_platform();
    sys.search.victory = 10;
    sys.search.max_samples = 100;
    sys.jobs = 2;
    sys
}

#[test]
fn warm_explore_runs_zero_mapper_searches_and_matches_cold_front() {
    let dir = tmpdir("roundtrip");
    let g = zoo::squeezenet1_1(1000);
    let sys = quick_sys();

    // Cold run: populates, then persists.
    let cold_cache = Arc::new(CostCache::new());
    let cold = ExploreRequest::chain().with_cache(Arc::clone(&cold_cache)).run(&g, &sys);
    assert!(cold_cache.misses() > 0, "cold run must actually evaluate layers");
    let path = cold_cache.save_to(&dir, &sys.search).unwrap();
    assert!(path.ends_with(COST_CACHE_FILE));

    // Warm run: every layer cost is a disk-loaded hit.
    let (warm_cache, status) = CostCache::load_from(&dir, &sys.search);
    assert_eq!(status, CacheLoad::Loaded(cold_cache.len()));
    let warm_cache = Arc::new(warm_cache);
    let warm = ExploreRequest::chain().with_cache(Arc::clone(&warm_cache)).run(&g, &sys);
    assert_eq!(
        warm_cache.misses(),
        0,
        "warm exploration performed {} layer evaluations",
        warm_cache.misses()
    );
    assert!(warm_cache.hits() > 0);

    // Bit-identical exploration results.
    assert_eq!(cold.pareto, warm.pareto);
    assert_eq!(cold.nsga_front, warm.nsga_front);
    assert_eq!(cold.favorite, warm.favorite);
    assert_eq!(cold.candidates.len(), warm.candidates.len());
    for (a, b) in cold.candidates.iter().zip(&warm.candidates) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{}", a.label);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{}", a.label);
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits(), "{}", a.label);
        assert_eq!(a.top1.to_bits(), b.top1.to_bits(), "{}", a.label);
        assert_eq!(a.memory_bytes, b.memory_bytes, "{}", a.label);
        assert_eq!(a.link_bytes, b.link_bytes, "{}", a.label);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_version_mismatched_and_missing_caches_are_ignored() {
    let search = SearchCfg { victory: 10, max_samples: 100, ..Default::default() };

    // Missing directory.
    let dir = tmpdir("missing");
    let (cache, status) = CostCache::load_from(&dir, &search);
    assert_eq!(status, CacheLoad::Missing);
    assert!(cache.is_empty());

    // Garbage bytes.
    let dir = tmpdir("garbage");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join(COST_CACHE_FILE), b"{not json at all").unwrap();
    let (cache, status) = CostCache::load_from(&dir, &search);
    assert_eq!(status, CacheLoad::Corrupt);
    assert!(cache.is_empty());

    // Valid JSON, wrong shape.
    std::fs::write(dir.join(COST_CACHE_FILE), b"[1, 2, 3]").unwrap();
    let (cache, status) = CostCache::load_from(&dir, &search);
    assert_eq!(status, CacheLoad::VersionMismatch);
    assert!(cache.is_empty());

    // Future format version.
    std::fs::write(
        dir.join(COST_CACHE_FILE),
        br#"{"version": 999, "search_fingerprint": "0", "entries": []}"#,
    )
    .unwrap();
    let (cache, status) = CostCache::load_from(&dir, &search);
    assert_eq!(status, CacheLoad::VersionMismatch);
    assert!(cache.is_empty());

    // Same version, different search settings.
    let dir2 = tmpdir("searchmismatch");
    CostCache::new().save_to(&dir2, &search).unwrap();
    let other = SearchCfg { victory: 11, max_samples: 100, ..Default::default() };
    let (cache, status) = CostCache::load_from(&dir2, &other);
    assert_eq!(status, CacheLoad::SearchMismatch);
    assert!(cache.is_empty());

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

#[test]
fn truncated_entry_is_corrupt_not_fatal() {
    let search = SearchCfg::default();
    let dir = tmpdir("truncated");
    std::fs::create_dir_all(&dir).unwrap();
    // Right version and fingerprint, but an entry missing its cost
    // fields: the whole file is rejected as corrupt, not panicked on.
    let text = format!(
        r#"{{"version": 1, "search_fingerprint": "{:016x}",
            "entries": [{{"kind": "mac", "acc": "00ff"}}]}}"#,
        search.fingerprint()
    );
    std::fs::write(dir.join(COST_CACHE_FILE), text).unwrap();
    let (cache, status) = CostCache::load_from(&dir, &search);
    assert_eq!(status, CacheLoad::Corrupt);
    assert!(cache.is_empty());
    std::fs::remove_dir_all(&dir).ok();
}
