//! Cross-subsystem invariant suite: property tests over randomly
//! generated chains and DAGs that pin down the structural contracts the
//! explorer, evaluator, and simulator all lean on.
//!
//! Each property is seeded through [`partir::testkit::property`], so a
//! reported failure names a replayable case index — no time-derived
//! randomness anywhere. The suite must stay green regardless of the
//! `--jobs` setting used elsewhere in the process; the final property
//! checks that directly by comparing `par_map` at 1 and 4 workers.

use partir::graph::partition::{
    assignment_chain_positions_into, is_convex, is_monotone, repair_monotone, segments,
    DagPartition,
};
use partir::graph::topo::{positions, topo_sort, TieBreak};
use partir::graph::{Act, Graph, LayerKind, NodeId};
use partir::memory::subset_memory_bytes;
use partir::testkit::{property, Gen};
use partir::util::parallel::par_map;
use partir::util::rng::Pcg32;

/// Materialize a random predecessor structure from [`Gen::dag`] into a
/// graph IR instance: node 0 is the sensor input, multi-input nodes
/// become `Add` (shape-preserving), single-input nodes become ReLU.
fn graph_from_preds(preds: &[Vec<usize>]) -> Graph {
    let mut g = Graph::new("invariant");
    let x = g.input(2, 4, 4);
    let mut ids = vec![x];
    for v in 1..preds.len() {
        let inputs: Vec<NodeId> = preds[v].iter().map(|&p| ids[p]).collect();
        let id = if inputs.len() >= 2 {
            g.add(LayerKind::Add, &inputs)
        } else {
            g.add(LayerKind::Activation(Act::Relu), &inputs)
        };
        ids.push(id);
    }
    g
}

/// A branch-free chain of `n_layers` ReLUs behind the input.
fn chain(n_layers: usize) -> Graph {
    let mut g = Graph::new("chain");
    let mut prev = g.input(4, 8, 8);
    for _ in 0..n_layers {
        prev = g.add(LayerKind::Activation(Act::Relu), &[prev]);
    }
    g
}

#[test]
fn repair_monotone_is_idempotent_and_pins_the_input() {
    property("repair_monotone idempotence", 150, |rng| {
        let n = Gen::usize_in(rng, 2..40);
        let k = Gen::usize_in(rng, 1..6);
        let g = graph_from_preds(&Gen::dag(rng, n, 0.15));
        let mut assign: Vec<usize> = (0..n).map(|_| Gen::usize_in(rng, 0..k)).collect();
        repair_monotone(&g, &mut assign);
        assert_eq!(assign[0], 0, "input not pinned to platform 0");
        assert!(is_monotone(&g, &assign), "repair left a non-monotone edge");
        let mut again = assign.clone();
        repair_monotone(&g, &mut again);
        assert_eq!(assign, again, "repair is not idempotent");
        // Already-valid assignments are fixed points, not merely mapped
        // to some other valid point.
        let mut valid = assign.clone();
        repair_monotone(&g, &mut valid);
        assert_eq!(valid, assign);
    });
}

#[test]
fn repaired_assignments_are_convex_and_partition_every_layer() {
    property("repair implies convexity", 150, |rng| {
        let n = Gen::usize_in(rng, 2..40);
        let k = Gen::usize_in(rng, 1..6);
        let g = graph_from_preds(&Gen::dag(rng, n, 0.2));
        let mut assign: Vec<usize> = (0..n).map(|_| Gen::usize_in(rng, 0..k)).collect();
        repair_monotone(&g, &mut assign);
        assert!(is_convex(&g, &assign), "monotone assignment not convex");
        // The induced stage partition is total: every layer lands in
        // exactly one stage and stage platforms ascend.
        let dp = DagPartition::from_assignment(&g, &assign, k).expect("repair output rejected");
        let total: usize = dp.stages.iter().map(|s| s.members.len()).sum();
        assert_eq!(total, g.len(), "stages do not tile the graph");
        assert!(
            dp.stages.windows(2).all(|w| w[0].platform < w[1].platform),
            "stages out of platform order"
        );
    });
}

#[test]
fn chain_positions_roundtrip_through_contiguous_assignments() {
    property("chain-positions roundtrip", 150, |rng| {
        let layers = Gen::usize_in(rng, 1..30);
        let g = chain(layers);
        let len = g.len();
        let k = Gen::usize_in(rng, 2..6);
        // Non-decreasing cut positions, each `< len - 1` (the `segments`
        // contract); duplicates encode idle platforms.
        let mut cuts: Vec<usize> = if len >= 2 {
            (0..k - 1).map(|_| Gen::usize_in(rng, 0..len - 1)).collect()
        } else {
            Vec::new()
        };
        cuts.sort_unstable();
        let order = topo_sort(&g, TieBreak::Deterministic);
        let segs = segments(len, &cuts);
        // segments() drops empty ranges, so rebuild the platform of each
        // schedule position from the cut vector directly: platform j owns
        // positions in (cuts[j-1], cuts[j]].
        let mut assign = vec![0usize; len];
        for (p, slot) in assign.iter_mut().enumerate() {
            let mut platform = 0;
            for &c in &cuts {
                if p > c {
                    platform += 1;
                }
            }
            *slot = platform;
        }
        // On a chain the deterministic order is the identity, so the
        // assignment is monotone by construction.
        assert!(is_monotone(&g, &assign));
        let pos = positions(&order, len);
        let mut bounds = Vec::new();
        let mut out = Vec::new();
        let ok = assignment_chain_positions_into(&assign, &pos, k, &mut bounds, &mut out);
        assert!(ok, "contiguous assignment judged non-chain");
        assert_eq!(out, cuts, "cut positions did not roundtrip");
        // The segment view agrees with the assignment view.
        let covered: usize = segs.iter().map(|s| s.len()).sum();
        assert_eq!(covered, len);
        // And the high-level DagPartition wrapper reports the same.
        let dp = DagPartition::from_assignment(&g, &assign, k).unwrap();
        assert_eq!(dp.as_chain_positions(&order, k), Some(cuts));
    });
}

#[test]
fn branch_parallel_assignments_never_claim_chain_form() {
    property("branch splits are not chains", 100, |rng| {
        // A diamond with the two middle branches on different platforms
        // is the canonical non-chain shape; embed one at a random depth.
        let stem = Gen::usize_in(rng, 0..8);
        let mut g = Graph::new("diamond");
        let mut prev = g.input(2, 4, 4);
        for _ in 0..stem {
            prev = g.add(LayerKind::Activation(Act::Relu), &[prev]);
        }
        let b = g.add(LayerKind::Activation(Act::Relu), &[prev]);
        let c = g.add(LayerKind::Activation(Act::Relu), &[prev]);
        let join = g.add(LayerKind::Add, &[b, c]);
        g.add(LayerKind::GlobalAvgPool, &[join]);
        let mut assign = vec![0usize; g.len()];
        assign[b.0] = 1;
        assign[join.0] = 1;
        assign[g.len() - 1] = 1;
        assert!(is_monotone(&g, &assign));
        let order = topo_sort(&g, TieBreak::Deterministic);
        let dp = DagPartition::from_assignment(&g, &assign, 2).unwrap();
        assert!(
            dp.is_branch_parallel(&order, 2),
            "b-on-1 / c-on-0 split misreported as a chain cut"
        );
    });
}

#[test]
fn subset_memory_dominates_every_member_layer() {
    property("subset memory >= per-layer max", 150, |rng| {
        let n = Gen::usize_in(rng, 2..40);
        let g = graph_from_preds(&Gen::dag(rng, n, 0.15));
        let mut r = Pcg32::seeded(Gen::usize_in(rng, 0..1 << 30) as u64);
        let order = topo_sort(&g, TieBreak::Random(&mut r));
        let bits = *[4u32, 8, 16, 32].get(Gen::usize_in(rng, 0..4)).unwrap();
        // Random non-empty member-position subset.
        let mut members: Vec<usize> =
            (0..n).filter(|_| Gen::usize_in(rng, 0..3) == 0).collect();
        if members.is_empty() {
            members.push(Gen::usize_in(rng, 0..n));
        }
        let whole = subset_memory_bytes(&g, &order, &members, bits);
        for &p in &members {
            let single = subset_memory_bytes(&g, &order, &[p], bits);
            assert!(
                whole >= single,
                "subset {whole} B < member {p} alone {single} B (bits {bits})"
            );
        }
        // Wider quantization widths never shrink the footprint.
        assert!(subset_memory_bytes(&g, &order, &members, 32) >= whole);
    });
}

#[test]
fn par_map_is_jobs_invariant() {
    property("par_map jobs identity", 50, |rng| {
        let xs = Gen::vec_f64(rng, 1..64, -100.0, 100.0);
        let f = |x: &f64| (x * 1.5).sin().to_bits();
        let one = par_map(1, &xs, f);
        let four = par_map(4, &xs, f);
        assert_eq!(one, four, "worker count changed par_map output");
    });
}
