//! Cross-layer replication properties (PR 6 acceptance surface).
//!
//! * **All-ones identity**: `evaluate_replicated(pos, [1,1,…])` is
//!   bit-identical to `evaluate(pos)` for every paper model on both
//!   paper presets — the replication axis is free when unused.
//! * **Analytic monotonicity**: doubling every slot's replica count
//!   never lowers Definition-4 throughput, keeps latency/top-1
//!   untouched, and reports slot memory additive across replica nodes
//!   while Definition 3 stays a per-node check.
//! * **Conservation**: a replicated deployment built from a real
//!   explored candidate neither drops nor duplicates requests under
//!   overload, under both dispatch policies.
//! * **Jobs identity**: a replicated cluster exploration is
//!   bit-identical for any `ExploreRequest::jobs` value.
//! * **Goodput**: replicating the bottleneck stage strictly raises
//!   simulated goodput under an overload storm.

use partir::config::SystemConfig;
use partir::coordinator::BatchPolicy;
use partir::explorer::{ExploreRequest, PlanEvaluator};
use partir::hw::CostCache;
use partir::sim::{self, Deployment, DispatchPolicy, Scenario, SimCfg};
use partir::zoo;
use std::sync::Arc;
use std::time::Duration;

fn quick(mut sys: SystemConfig) -> SystemConfig {
    sys.search.victory = 10;
    sys.search.max_samples = 80;
    sys
}

/// A deterministic spread of cut-position vectors for a `k`-platform
/// chain over a `layers`-long schedule: all-on-first, all-on-last,
/// evenly spaced, and a lopsided split.
fn sample_cuts(layers: usize, k: usize) -> Vec<Vec<usize>> {
    let last = layers - 1;
    let spread: Vec<usize> = (1..k).map(|i| (i * layers / k).min(last)).collect();
    let lopsided: Vec<usize> = (1..k).map(|i| (i * layers / (4 * k)).min(last)).collect();
    vec![vec![0; k - 1], vec![last; k - 1], spread, lopsided]
}

#[test]
fn all_ones_replicas_identity() {
    // The CI grep-gate keys on this test name: replicas = [1,1,…] must
    // stay bit-identical to the unreplicated evaluation everywhere.
    let cache = Arc::new(CostCache::new());
    for sys in [
        quick(SystemConfig::paper_two_platform()),
        quick(SystemConfig::paper_four_platform()),
    ] {
        let k = sys.platforms.len();
        let ones = vec![1usize; k];
        for model in zoo::PAPER_MODELS {
            let g = zoo::build(model).unwrap();
            let ev = PlanEvaluator::with_cache(&g, &sys, Arc::clone(&cache));
            for pos in sample_cuts(g.len(), k) {
                let plain = ev.evaluate(&pos);
                let rep = ev.evaluate_replicated(&pos, &ones);
                assert_eq!(
                    format!("{plain:?}"),
                    format!("{rep:?}"),
                    "{model} ({k} platforms) diverges at cuts {pos:?}"
                );
            }
        }
    }
}

#[test]
fn doubling_replicas_is_throughput_monotone_and_memory_additive() {
    let sys = quick(SystemConfig::paper_two_platform());
    let g = zoo::build("squeezenet1_1").unwrap();
    let ev = PlanEvaluator::new(&g, &sys);
    let mut strict = 0usize;
    for pos in sample_cuts(g.len(), 2) {
        let r1 = ev.evaluate(&pos);
        let r2 = ev.evaluate_replicated(&pos, &[2, 2]);
        assert!(r2.throughput >= r1.throughput, "throughput dropped at {pos:?}");
        if r2.throughput > r1.throughput {
            strict += 1;
        }
        // Single-inference metrics are replica-blind.
        assert_eq!(r1.latency_s, r2.latency_s, "latency changed at {pos:?}");
        assert_eq!(r1.top1, r2.top1);
        assert_eq!(r1.link_bytes, r2.link_bytes);
        // Reported slot memory is additive across replica nodes…
        for j in 0..2 {
            assert_eq!(r2.memory_bytes[j], 2 * r1.memory_bytes[j], "slot {j} at {pos:?}");
        }
        // …while Definition 3 stays per-node: feasibility is unchanged.
        assert_eq!(r1.feasible(), r2.feasible(), "feasibility flipped at {pos:?}");
    }
    assert!(strict > 0, "no compute-bound cut gained throughput from 2x replicas");
}

#[test]
fn replicated_deployment_conserves_requests_under_overload() {
    // Take a real explored split, replicate its first stage 3x, and
    // storm it well past capacity: every request must leave the system
    // exactly once (completed ok, or dropped) under both policies.
    let sys = quick(SystemConfig::paper_two_platform());
    let g = zoo::build("squeezenet1_1").unwrap();
    let ex = ExploreRequest::chain().run(&g, &sys);
    let best = ex
        .candidates
        .iter()
        .filter(|c| c.feasible() && c.partitions == 2)
        .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
        .expect("a feasible split");
    let dep = Deployment::from_candidate(best, &sys).replicate_stage(0, 3);
    let n = 20_000usize;
    let storm = Scenario::steady(n, 4.0 * best.throughput);
    for dispatch in [DispatchPolicy::RoundRobin, DispatchPolicy::QueueAware] {
        let cfg = SimCfg {
            batch: BatchPolicy::new(8, Duration::from_millis(2)),
            queue_depth: 32,
            seed: 11,
            dispatch,
        };
        let r = sim::simulate(&dep, &cfg, &storm);
        assert_eq!(r.pipeline.completions.len(), n, "{dispatch:?}: lost completions");
        assert_eq!(
            r.pipeline.completed() + r.dropped as usize,
            n,
            "{dispatch:?}: completed + dropped != offered"
        );
        for (i, c) in r.pipeline.completions.iter().enumerate() {
            assert_eq!(c.id, i as u64, "{dispatch:?}: duplicate or reordered completion");
        }
    }
}

#[test]
fn replicated_cluster_exploration_is_jobs_invariant() {
    // The --jobs contract survives the replication axis: same candidates,
    // same front, same favorite for any worker count.
    let sys = quick(SystemConfig::cluster(4));
    let g = zoo::build("squeezenet1_1").unwrap();
    let cache = Arc::new(CostCache::new());
    let a = ExploreRequest::chain().with_cache(Arc::clone(&cache)).jobs(1).run(&g, &sys);
    let b = ExploreRequest::chain().with_cache(Arc::clone(&cache)).jobs(4).run(&g, &sys);
    assert!(!a.candidates.is_empty());
    assert!(a.candidates.iter().any(|c| c.plan.iter().any(|p| p.replicas > 1)));
    assert_eq!(
        format!("{:?}", a.candidates),
        format!("{:?}", b.candidates),
        "candidate lists diverge between jobs=1 and jobs=4"
    );
    assert_eq!(a.pareto, b.pareto);
    assert_eq!(a.favorite, b.favorite);
}

#[test]
fn replicating_the_bottleneck_raises_goodput_under_overload() {
    // 5 ms bottleneck caps the chain near 200/s; at 500/s offered, a
    // 3x replica bank must convert the headroom into strictly higher
    // goodput.
    let base = Deployment::synthetic("goodput", &[1e-4, 0.005], 4096);
    let rep = base.clone().replicate_stage(1, 3);
    let cfg = SimCfg {
        batch: BatchPolicy::new(4, Duration::from_millis(1)),
        queue_depth: 64,
        seed: 3,
        dispatch: DispatchPolicy::QueueAware,
    };
    let storm = Scenario::steady(10_000, 500.0);
    let r1 = sim::simulate(&base, &cfg, &storm);
    let r3 = sim::simulate(&rep, &cfg, &storm);
    assert!(
        r3.goodput > r1.goodput,
        "replication did not raise goodput: {} vs {}",
        r3.goodput,
        r1.goodput
    );
    assert!(r3.dropped < r1.dropped);
}
