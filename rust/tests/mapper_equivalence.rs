//! Acceptance gate for the mapper-kernel optimization (PR 2 tentpole):
//! the bound-pruned, allocation-free kernel must pick a **bit-identical**
//! mapping (latency, energy, utilization, DRAM bytes, mapping string) to
//! the straight-line reference kernel for every workload, accelerator,
//! objective and search budget.
//!
//! CI runs this test file by name and fails if it is skipped or renamed
//! (see `.github/workflows/ci.yml`).

use partir::hw::{mapper, presets, Accelerator, ConvWorkload, LayerCost, Objective, SearchCfg};
use partir::testkit::{property, Gen};
use partir::zoo;

/// Every distinct MAC workload across all six paper models.
fn workload_pool() -> Vec<(String, ConvWorkload)> {
    let mut out: Vec<(String, ConvWorkload)> = Vec::new();
    for model in zoo::PAPER_MODELS {
        let g = zoo::build(model).unwrap();
        for node in &g.nodes {
            if let Some(wl) = ConvWorkload::from_node(&g, node) {
                // Dedup structurally identical shapes to keep the pool lean.
                if !out.iter().any(|(_, w)| w.signature() == wl.signature()) {
                    out.push((format!("{model}/{}", node.name), wl));
                }
            }
        }
    }
    out
}

fn assert_identical(tag: &str, a: &LayerCost, b: &LayerCost) {
    assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{tag}: latency diverged");
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{tag}: energy diverged");
    assert_eq!(
        a.utilization.to_bits(),
        b.utilization.to_bits(),
        "{tag}: utilization diverged"
    );
    assert_eq!(a.macs, b.macs, "{tag}: macs diverged");
    assert_eq!(a.dram_bytes, b.dram_bytes, "{tag}: dram bytes diverged");
    assert_eq!(a.mapping_desc, b.mapping_desc, "{tag}: chosen mapping diverged");
}

#[test]
fn kernel_equivalence_random_workloads_all_models_both_presets() {
    let pool = workload_pool();
    assert!(pool.len() > 30, "expected a rich workload pool, got {}", pool.len());
    let accs: [Accelerator; 2] = [presets::eyeriss_like(), presets::simba_like()];
    let objectives = [Objective::Edp, Objective::Latency, Objective::Energy];
    property("bound-pruned kernel bit-identical to reference", 60, |rng| {
        let (name, wl) = &pool[Gen::usize_in(rng, 0..pool.len())];
        let acc = &accs[Gen::usize_in(rng, 0..accs.len())];
        let cfg = SearchCfg {
            victory: Gen::usize_in(rng, 5..40),
            max_samples: Gen::usize_in(rng, 50..350),
            seed: Gen::u32_in(rng, 0..u32::MAX) as u64,
            objective: objectives[Gen::usize_in(rng, 0..objectives.len())],
        };
        let (fast, fast_stats) = mapper::map_layer_with_stats(acc, wl, &cfg);
        let (reference, ref_stats) = mapper::reference::map_layer_with_stats(acc, wl, &cfg);
        let tag = format!("{name} on {} ({:?})", acc.name, cfg.objective);
        assert_identical(&tag, &fast, &reference);
        // The prune must never perturb the search trajectory: both
        // kernels draw the same number of samples from the same stream.
        assert_eq!(fast_stats.samples, ref_stats.samples, "{tag}: RNG streams diverged");
    });
}

#[test]
fn kernel_equivalence_full_default_budget() {
    // The paper's actual setting (victory=100, max_samples=4000) on a
    // reuse-rich conv, a depthwise conv and an FC layer.
    let cfg = SearchCfg::default();
    for (model, layer) in
        [("vgg16", "Conv_5"), ("efficientnet_b0", "Conv_1"), ("resnet50", "Gemm_0")]
    {
        let g = zoo::build(model).unwrap();
        let wl = ConvWorkload::from_node(&g, g.by_name(layer).unwrap()).unwrap();
        for acc in [presets::eyeriss_like(), presets::simba_like()] {
            let fast = mapper::map_layer(&acc, &wl, &cfg);
            let reference = mapper::reference::map_layer(&acc, &wl, &cfg);
            assert_identical(&format!("{model}/{layer} on {}", acc.name), &fast, &reference);
        }
    }
}

#[test]
fn pruning_actually_fires() {
    // Guard against the bound silently degenerating to -inf (which would
    // keep equivalence but lose the speedup): on a standard workload a
    // healthy fraction of samples must be rejected without full
    // evaluation.
    let g = zoo::vgg16(1000);
    let wl = ConvWorkload::from_node(&g, g.by_name("Conv_5").unwrap()).unwrap();
    let acc = presets::eyeriss_like();
    let (_, stats) = mapper::map_layer_with_stats(&acc, &wl, &SearchCfg::default());
    assert!(stats.samples > 0);
    assert!(
        stats.pruned * 10 >= stats.samples,
        "bound prune fired on only {}/{} samples",
        stats.pruned,
        stats.samples
    );
}
