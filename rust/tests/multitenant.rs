//! Multi-tenant determinism matrix: joint exploration and shared-cluster
//! serving must be bit-identical across `--jobs 1/2/4` and across repeat
//! runs, and single-tenant requests must be untouched by the tenant
//! machinery (same worker-count identity they had before it existed).

use partir::config::{SystemConfig, TenantSet, TenantSpec};
use partir::explorer::{Exploration, ExploreRequest};
use partir::sim::{evaluate_tenants, Scenario, SimCfg};
use partir::util::hash::Fnv64;
use partir::zoo;

fn quick_sys() -> SystemConfig {
    let mut sys = SystemConfig::paper_two_platform();
    sys.search.victory = 5;
    sys.search.max_samples = 50;
    sys
}

fn roster() -> TenantSet {
    TenantSet {
        tenants: vec![
            TenantSpec { rate: 20.0, ..TenantSpec::new("tiny_cnn") },
            TenantSpec { rate: 10.0, priority: 2.0, ..TenantSpec::new("squeezenet1_1") },
        ],
        ..TenantSet::default()
    }
}

#[test]
fn joint_exploration_is_bit_identical_across_jobs_and_reruns() {
    let sys = quick_sys();
    let set = roster();
    let fp = |jobs: usize| {
        ExploreRequest::chain().tenants(set.clone()).jobs(jobs).run_tenants(&sys).fingerprint()
    };
    let one = fp(1);
    assert_eq!(one, fp(2), "--jobs 2 changed the joint front");
    assert_eq!(one, fp(4), "--jobs 4 changed the joint front");
    assert_eq!(one, fp(1), "repeat run changed the joint front");
}

#[test]
fn tenant_serving_evaluation_is_bit_identical_across_jobs_and_reruns() {
    let sys = quick_sys();
    let ex = ExploreRequest::chain().tenants(roster()).run_tenants(&sys);
    assert!(!ex.candidates.is_empty(), "no joint candidates to serve");
    let sc = Scenario::steady(200, 30.0);
    let cfg = SimCfg { seed: 11, ..SimCfg::from_system(&sys) };
    let fp = |jobs: usize| -> Vec<(usize, u64)> {
        evaluate_tenants(&ex, &sys, 200, &sc, &cfg, jobs)
            .iter()
            .map(|r| (r.index, r.report.fingerprint()))
            .collect()
    };
    let one = fp(1);
    assert_eq!(one, fp(2), "--jobs 2 changed the serving ranking");
    assert_eq!(one, fp(4), "--jobs 4 changed the serving ranking");
    assert_eq!(one, fp(1), "repeat evaluation changed the serving ranking");
}

/// Digest every externally observable field of a single-tenant
/// exploration (the pre-existing result type has no fingerprint of its
/// own; the guard below needs exact equality, not spot checks).
fn exploration_fp(ex: &Exploration) -> u64 {
    let mut h = Fnv64::new();
    h.write_bytes(ex.model.as_bytes());
    h.write_u64(ex.candidates.len() as u64);
    for c in &ex.candidates {
        h.write_bytes(c.label.as_bytes());
        h.write_f64(c.latency_s);
        h.write_f64(c.energy_j);
        h.write_f64(c.throughput);
        h.write_f64(c.top1);
        h.write_u64(c.link_bytes);
        h.write_f64(c.violation);
        h.write_u64(c.partitions as u64);
        for &p in &c.positions {
            h.write_usize(p);
        }
        for &m in &c.memory_bytes {
            h.write_u64(m);
        }
    }
    for &i in ex.pareto.iter().chain(&ex.nsga_front) {
        h.write_usize(i);
    }
    h.write_u64(ex.favorite.map_or(u64::MAX, |f| f as u64));
    h.finish()
}

#[test]
fn single_tenant_requests_are_unaffected_by_the_tenant_machinery() {
    let sys = quick_sys();
    let g = zoo::build("squeezenet1_1").unwrap();
    // A request that never mentions tenants must produce the same
    // exploration whether or not a roster exists in the config, at any
    // worker count.
    let base = exploration_fp(&ExploreRequest::chain().jobs(1).run(&g, &sys));
    assert_eq!(
        base,
        exploration_fp(&ExploreRequest::chain().jobs(4).run(&g, &sys)),
        "--jobs changed the single-tenant exploration"
    );
    let mut with_roster = sys.clone();
    with_roster.tenants = roster().tenants;
    assert_eq!(
        base,
        exploration_fp(&ExploreRequest::chain().jobs(1).run(&g, &with_roster)),
        "a configured [[tenants]] roster leaked into single-tenant runs"
    );
}
