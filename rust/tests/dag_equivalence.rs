//! Acceptance gate for the DAG-aware explorer (CI greps for
//! `dag_matches_chain_on_sequential_models`):
//!
//! 1. On purely sequential models the DAG explorer must reproduce the
//!    two-platform chain exploration **bit-identically** — every
//!    monotone convex assignment of a chain graph is a linear cut, so
//!    the generalized search collapses onto the paper's Definition-1
//!    space.
//! 2. On branchy models (GoogLeNet's inception blocks) the DAG space
//!    is strictly larger: branch-parallel plans exist, evaluate
//!    feasibly, flow into the serving simulator as fork/join stage
//!    graphs, and the explored front never loses throughput relative
//!    to the chain front (it is a superset).

use partir::config::SystemConfig;
use partir::explorer::reference::DagReference;
use partir::explorer::{
    exhaustive_pareto, sweep_dag_front, CandidateMetrics, EvalScratch, ExploreRequest,
    PlanEvaluator,
};
use partir::graph::partition::{dag_cuts, repair_monotone};
use partir::graph::Graph;
use partir::hw::CostCache;
use partir::sim::{self, Deployment, Scenario, SimCfg};
use partir::util::rng::Pcg32;
use partir::zoo;
use std::sync::Arc;

fn quick_sys() -> SystemConfig {
    let mut sys = SystemConfig::paper_two_platform();
    sys.search.victory = 10;
    sys.search.max_samples = 100;
    sys.jobs = 2;
    sys
}

/// A model is sequential when no layer fans out or joins: every node
/// has at most one input and at most one consumer.
fn is_sequential(g: &Graph) -> bool {
    g.nodes.iter().all(|n| n.inputs.len() <= 1)
        && g.successors().iter().all(|s| s.len() <= 1)
}

#[test]
fn dag_matches_chain_on_sequential_models() {
    let mut checked = 0;
    for name in zoo::PAPER_MODELS.iter().copied().chain(["tiny_cnn"]) {
        let g = zoo::build(name).unwrap();
        if !is_sequential(&g) {
            continue;
        }
        checked += 1;
        let sys = quick_sys();
        let chain = ExploreRequest::chain().run(&g, &sys);
        let dag = ExploreRequest::dag().run(&g, &sys);
        assert_eq!(chain.candidates.len(), dag.candidates.len(), "{name}: extra candidates");
        assert_eq!(chain.pareto, dag.pareto, "{name}: Pareto front diverged");
        assert_eq!(chain.favorite, dag.favorite, "{name}: favorite diverged");
        assert_eq!(chain.nsga_front, dag.nsga_front, "{name}: NSGA front diverged");
        for (a, b) in chain.candidates.iter().zip(&dag.candidates) {
            assert_eq!(a.label, b.label, "{name}");
            assert_eq!(a.positions, b.positions, "{name}: {}", a.label);
            assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{name}: {}", a.label);
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{name}: {}", a.label);
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits(), "{name}: {}", a.label);
            assert_eq!(a.top1.to_bits(), b.top1.to_bits(), "{name}: {}", a.label);
            assert_eq!(a.link_bytes, b.link_bytes, "{name}: {}", a.label);
            assert_eq!(a.memory_bytes, b.memory_bytes, "{name}: {}", a.label);
            assert!(b.assign.is_none(), "{name}: {} marked branch-parallel", a.label);
        }
    }
    // VGG-16 and the tiny CNN are branch-free; the invariant must have
    // actually been exercised.
    assert!(checked >= 2, "only {checked} sequential zoo models found");
}

/// Walk one inception-style branch of `g` backwards from a Concat
/// input and move it (plus everything from the join onward) to
/// platform 1 — a guaranteed branch-parallel monotone assignment.
fn branch_split_assignment(g: &Graph) -> Option<Vec<usize>> {
    let succ = g.successors();
    let concat = g.nodes.iter().find(|n| n.inputs.len() >= 3)?;
    let mut assign = vec![0usize; g.len()];
    // Everything at or after the join runs on platform 1 (ids are
    // topologically ordered, so id-order suffices for "after").
    for id in concat.id.0..g.len() {
        assign[id] = 1;
    }
    // One branch: walk single-input ancestors of the join's second
    // input until hitting the block input (which fans out).
    let mut cur = concat.inputs[1];
    loop {
        assign[cur.0] = 1;
        let node = g.node(cur);
        if node.inputs.len() != 1 {
            break;
        }
        let prev = node.inputs[0];
        if succ[prev.0].len() > 1 {
            break; // the block input feeding every branch stays on 0
        }
        cur = prev;
    }
    Some(assign)
}

#[test]
fn googlenet_supports_branch_parallel_plans_end_to_end() {
    let g = zoo::googlenet(1000);
    let sys = quick_sys();
    let ev = PlanEvaluator::new(&g, &sys);

    // A constructed inception split is genuinely branch-parallel,
    // feasible, and internally consistent.
    let assign = branch_split_assignment(&g).expect("googlenet has inception joins");
    let m = ev.evaluate_dag(&assign);
    assert!(m.branch_parallel(), "inception split should not be chain-expressible");
    assert_eq!(m.partitions, 2);
    assert!(m.feasible(), "{:?}", m.violations);
    assert!(m.latency_s > 0.0 && m.throughput > 0.0 && m.energy_j > 0.0);
    let plan_link: u64 = m
        .plan
        .iter()
        .flat_map(|s| s.edges.iter())
        .map(|e| e.bytes * e.hops)
        .sum();
    assert_eq!(plan_link, m.link_bytes, "plan edges must account every wire byte");

    // It deploys in the discrete-event simulator as a fork/join stage
    // graph and serves traffic deterministically.
    let dep = Deployment::from_candidate(&m, &sys);
    assert!(
        dep.edges.iter().any(|es| es.iter().filter(|e| e.to.is_some()).count() >= 1),
        "deployment lost its stage graph"
    );
    let sc = Scenario::steady(20_000, 1.2 * m.throughput);
    let cfg = SimCfg { seed: 7, ..Default::default() };
    let a = sim::simulate(&dep, &cfg, &sc);
    let b = sim::simulate(&dep, &cfg, &sc);
    assert_eq!(a.fingerprint(), b.fingerprint(), "branch-parallel sim not deterministic");
    assert_eq!(a.pipeline.completions.len(), 20_000);
    assert!(a.throughput() > 0.0);
}

fn assert_candidates_bit_identical(a: &CandidateMetrics, b: &CandidateMetrics, what: &str) {
    assert_eq!(a.label, b.label, "{what}");
    assert_eq!(a.positions, b.positions, "{what}: {}", a.label);
    assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{what}: {}", a.label);
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{what}: {}", a.label);
    assert_eq!(a.throughput.to_bits(), b.throughput.to_bits(), "{what}: {}", a.label);
    assert_eq!(a.top1.to_bits(), b.top1.to_bits(), "{what}: {}", a.label);
    assert_eq!(a.memory_bytes, b.memory_bytes, "{what}: {}", a.label);
    assert_eq!(a.link_bytes, b.link_bytes, "{what}: {}", a.label);
    assert_eq!(a.partitions, b.partitions, "{what}: {}", a.label);
    assert_eq!(a.plan, b.plan, "{what}: {}", a.label);
    assert_eq!(a.assign, b.assign, "{what}: {}", a.label);
    assert_eq!(a.violation.to_bits(), b.violation.to_bits(), "{what}: {}", a.label);
    assert_eq!(a.violations, b.violations, "{what}: {}", a.label);
}

/// The incremental DAG evaluator (stage-granular cost cache, reused
/// `EvalScratch`, bound-pruned sweep) must be **bit-identical** to the
/// preserved pre-cache reference path — per candidate and per Pareto
/// front — for every zoo model under both system presets, and the full
/// DAG exploration must be identical across `--jobs 1` vs `--jobs N`.
/// (CI greps that this test runs.)
#[test]
fn incremental_dag_eval_bit_identical() {
    for name in zoo::PAPER_MODELS.iter().copied().chain(["tiny_cnn"]) {
        let g = zoo::build(name).unwrap();
        // One shared layer-cost cache per model: both presets use the
        // same accelerators, so the mapper runs once per layer shape.
        let cache = Arc::new(CostCache::new());
        for (pi, mut sys) in
            [SystemConfig::paper_two_platform(), SystemConfig::paper_four_platform()]
                .into_iter()
                .enumerate()
        {
            sys.search.victory = 10;
            sys.search.max_samples = 100;
            let k = sys.platforms.len();
            let what = format!("{name}/preset{pi}");
            let ev = PlanEvaluator::with_cache(&g, &sys, Arc::clone(&cache));

            // Genome pool: enumerated two-platform convex cuts (chain
            // prefixes on sequential models, branch splits on branchy
            // ones) plus repaired random k-platform genomes.
            let mut assigns = dag_cuts(&g, 48);
            let mut rng = Pcg32::seeded(2026 + pi as u64);
            for _ in 0..16 {
                let mut a: Vec<usize> = (0..g.len()).map(|_| rng.gen_usize(0, k)).collect();
                repair_monotone(&g, &mut a);
                assigns.push(a);
            }

            // Per-candidate bit identity: reference (fresh allocations,
            // Mutex memo) vs incremental (warm cache + reused scratch).
            let reference = DagReference::new(&ev);
            let mut scratch = EvalScratch::new();
            let mut ref_cands: Vec<CandidateMetrics> = Vec::new();
            for a in &assigns {
                let r = reference.evaluate_dag(a);
                let m = ev.evaluate_dag_in(a, &mut scratch);
                assert_candidates_bit_identical(&r, &m, &what);
                ref_cands.push(r);
            }

            // Front identity: unpruned cold runs vs the warm, pruned,
            // scratch-reusing sweep.
            let ref_front: Vec<CandidateMetrics> =
                exhaustive_pareto(&ref_cands, &sys.pareto_metrics)
                    .into_iter()
                    .map(|i| ref_cands[i].clone())
                    .collect();
            ev.clear_stage_cache();
            let (cold_front, cold_stats) = sweep_dag_front(&ev, &assigns, false);
            let (warm_front, warm_stats) = sweep_dag_front(&ev, &assigns, true);
            assert_eq!(cold_stats.evaluated, assigns.len(), "{what}: cold sweep must not prune");
            assert_eq!(
                warm_stats.evaluated + warm_stats.pruned,
                assigns.len(),
                "{what}: sweep lost genomes"
            );
            assert_eq!(ref_front.len(), cold_front.len(), "{what}: ref vs cold front size");
            assert_eq!(cold_front.len(), warm_front.len(), "{what}: cold vs warm front size");
            for ((r, c), w) in ref_front.iter().zip(&cold_front).zip(&warm_front) {
                assert_candidates_bit_identical(r, c, &format!("{what}: ref vs cold"));
                assert_candidates_bit_identical(c, w, &format!("{what}: cold vs warm+pruned"));
            }
        }

        // Full DAG exploration: serial vs parallel workers, identical
        // fronts (the cache/scratch machinery is shard-shared).
        let mut s1 = quick_sys();
        s1.jobs = 1;
        let mut sn = quick_sys();
        sn.jobs = 3;
        let a = ExploreRequest::dag().with_cache(Arc::clone(&cache)).run(&g, &s1);
        let b = ExploreRequest::dag().with_cache(Arc::clone(&cache)).run(&g, &sn);
        assert_eq!(a.pareto, b.pareto, "{name}: jobs changed the Pareto front");
        assert_eq!(a.favorite, b.favorite, "{name}: jobs changed the favorite");
        assert_eq!(a.candidates.len(), b.candidates.len(), "{name}");
        for (x, y) in a.candidates.iter().zip(&b.candidates) {
            assert_candidates_bit_identical(x, y, &format!("{name}: jobs 1 vs 3"));
        }
    }
}

#[test]
fn dag_front_never_loses_throughput_on_googlenet() {
    // The DAG exploration is a superset of the chain exploration, so
    // its best feasible throughput can only match or beat the chain's.
    let g = zoo::googlenet(1000);
    let sys = quick_sys();
    let chain = ExploreRequest::chain().run(&g, &sys);
    let dag = ExploreRequest::dag().run(&g, &sys);
    let best = |ex: &partir::explorer::Exploration| {
        ex.candidates
            .iter()
            .filter(|c| c.feasible())
            .map(|c| c.throughput)
            .fold(0.0f64, f64::max)
    };
    assert!(
        best(&dag) >= best(&chain),
        "dag best {} < chain best {}",
        best(&dag),
        best(&chain)
    );
    // The generalized space was actually searched.
    assert!(dag.candidates.len() >= chain.candidates.len());
}
