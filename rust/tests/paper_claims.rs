//! Shape assertions for the paper's headline claims (§V). We do not
//! assert absolute numbers — our substrate is an analytical simulator,
//! not the authors' Timeloop testbed — but who wins, in which direction,
//! and by roughly what factor must match. EXPERIMENTS.md records the
//! exact measured values next to the paper's.

use partir::config::{Metric, SystemConfig};
use partir::explorer::ExploreRequest;
use partir::graph::topo::{topo_sort, TieBreak};
use partir::memory;
use partir::report::throughput_gain;
use partir::zoo;

fn sys() -> SystemConfig {
    let mut sys = SystemConfig::paper_two_platform();
    // Full-budget search is exercised by `cargo bench`; a mid budget
    // keeps this suite fast while staying well-converged.
    sys.search.victory = 40;
    sys.search.max_samples = 600;
    sys
}

/// §V headline: "we can achieve a 47.5% throughput increase for
/// EfficientNet-B0 inference partitioned onto two platforms".
#[test]
fn efficientnet_pipelined_throughput_gain_is_large() {
    let ex = ExploreRequest::chain().run(&zoo::efficientnet_b0(1000), &sys());
    let (_, gain) = throughput_gain(&ex).expect("gain");
    assert!(
        (25.0..80.0).contains(&gain),
        "EfficientNet-B0 gain {gain:.1}% (paper: +47.5%)"
    );
}

/// Fig 2(b): ResNet-50 gains ~29% throughput from pipelining.
#[test]
fn resnet_pipelined_throughput_gain_is_moderate() {
    let ex = ExploreRequest::chain().run(&zoo::resnet50(1000), &sys());
    let (_, gain) = throughput_gain(&ex).expect("gain");
    assert!(
        (15.0..70.0).contains(&gain),
        "ResNet-50 gain {gain:.1}% (paper: +29%)"
    );
}

/// Fig 2(a)/(d): for VGG-16 and SqueezeNet an early-ReLU partition point
/// beats at least one single-platform reference on BOTH latency and
/// energy simultaneously.
#[test]
fn early_relu_partition_dominates_a_single_platform_reference() {
    for model in ["vgg16", "squeezenet1_1"] {
        let ex = ExploreRequest::chain().run(&zoo::build(model).unwrap(), &sys());
        let singles: Vec<&partir::explorer::CandidateMetrics> =
            ex.candidates.iter().filter(|c| c.partitions == 1).collect();
        let found = ex
            .candidates
            .iter()
            .filter(|c| c.partitions == 2 && c.feasible())
            .any(|c| {
                singles
                    .iter()
                    .any(|s| c.latency_s < s.latency_s && c.energy_j < s.energy_j)
            });
        assert!(found, "{model}: no split beats a single platform on latency AND energy");
    }
}

/// Fig 2(c)/(f): "the later the partitioning of the network is
/// performed, the higher the top-1 accuracy" — and single-platform
/// extremes bound the range.
#[test]
fn accuracy_guideline_later_is_better() {
    for model in ["resnet50", "efficientnet_b0"] {
        let ex = ExploreRequest::chain().run(&zoo::build(model).unwrap(), &sys());
        let splits: Vec<(usize, f64)> = ex
            .candidates
            .iter()
            .filter(|c| c.partitions == 2)
            .map(|c| (c.positions[0], c.top1))
            .collect();
        let earliest = splits.iter().min_by_key(|&&(p, _)| p).unwrap();
        let latest = splits.iter().max_by_key(|&&(p, _)| p).unwrap();
        assert!(latest.1 > earliest.1, "{model}: top1 not increasing");
        let all_on_b = ex.candidates.iter().find(|c| c.label == "all-on-B").unwrap();
        let all_on_a = ex.candidates.iter().find(|c| c.label == "all-on-A").unwrap();
        assert!(all_on_a.top1 > all_on_b.top1, "{model}: 16-bit EYR should beat 8-bit SMB");
    }
}

/// §V-B: "the throughput can drop significantly if the partitioning
/// point is not chosen carefully" — the split-point spread is large.
#[test]
fn throughput_spread_across_cut_points_is_significant() {
    let ex = ExploreRequest::chain().run(&zoo::resnet50(1000), &sys());
    let tputs: Vec<f64> = ex
        .candidates
        .iter()
        .filter(|c| c.partitions == 2)
        .map(|c| c.throughput)
        .collect();
    let best = tputs.iter().cloned().fold(0.0, f64::max);
    let worst = tputs.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(best / worst > 1.5, "spread {best}/{worst} too small");
}

/// Fig 3: EfficientNet-B0 platform-A memory grows monotonically with the
/// cut position and platform-B memory shrinks; total stays within 2x of
/// the whole-network footprint (paper: "the memory size required for
/// EfficientNet-B0 increases the later the partitioning is performed").
#[test]
fn fig3_memory_growth_shape() {
    let g = zoo::efficientnet_b0(1000);
    let order = topo_sort(&g, TieBreak::Deterministic);
    let cuts = partir::graph::partition::clean_cuts(&g, &order);
    let mut prev_a = 0u64;
    let whole = memory::segment_memory_bytes(&g, &order, 0..g.len(), 16);
    for c in &cuts {
        let ma = memory::segment_memory_bytes(&g, &order, 0..c.pos + 1, 16);
        let mb = memory::segment_memory_bytes(&g, &order, c.pos + 1..g.len(), 16);
        assert!(ma + mb <= 2 * whole, "memory blow-up at {}", c.pos);
        // Parameter mass is monotone; the activation peak adds at most
        // its own bounded term, so A-memory should never shrink by more
        // than the largest feature map (few MB).
        assert!(ma + (4 << 20) >= prev_a, "A memory collapsed at {}", c.pos);
        prev_a = ma;
    }
    // The early-cut memory must be far below the late-cut memory.
    let first = memory::segment_memory_bytes(&g, &order, 0..cuts[2].pos + 1, 16);
    let last = memory::segment_memory_bytes(&g, &order, 0..cuts[cuts.len() - 1].pos + 1, 16);
    assert!(last > 4 * first, "no growth: first {first} last {last}");
}

/// Table I row for "Our Proposal": the framework covers all six
/// optimization metrics — every candidate carries them.
#[test]
fn all_six_metrics_are_reported() {
    let ex = ExploreRequest::chain().run(&zoo::googlenet(1000), &sys());
    let c = ex.favorite_metrics().unwrap();
    for m in [
        Metric::Latency,
        Metric::Energy,
        Metric::Throughput,
        Metric::Top1,
        Metric::LinkBytes,
        Metric::Memory,
    ] {
        let v = c.value(m);
        assert!(v.is_finite(), "{:?} missing", m);
    }
}
