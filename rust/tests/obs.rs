//! Observability inertness gate (PR 8 acceptance): turning the
//! deterministic observability layer on must not move a single bit of
//! any result — exploration fronts, `SimReport` fingerprints, and
//! `AdaptiveReport` fingerprints are identical with a live registry or
//! a dormant one, for any `--jobs` value. On top of that, the exported
//! Chrome trace must be valid JSON with per-lane monotone timestamps,
//! the metrics snapshot must round-trip through CSV exactly, and a
//! failover run must surface the controller's migration window as a
//! virtual-clock span.

use partir::config::SystemConfig;
use partir::explorer::{
    CandidateMetrics, Exploration, ExplorationTiming, ExploreRequest, PlanEdge, StagePlan,
};
use partir::obs::{chrome_trace, Registry, Snapshot};
use partir::sim::{
    compare_adaptive, evaluate_front, simulate, simulate_obs, Deployment, Scenario, SimCfg,
};
use partir::zoo;
use std::sync::Arc;

fn quick_sys() -> SystemConfig {
    let mut sys = SystemConfig::paper_two_platform();
    sys.search.victory = 10;
    sys.search.max_samples = 100;
    sys
}

fn obs_sys() -> SystemConfig {
    let mut sys = quick_sys();
    sys.obs.activate();
    sys
}

/// Same laxer improvement bar as `tests/adaptive.rs`, so the fault
/// presets migrate by construction.
fn acfg() -> partir::config::AdaptiveCfg {
    partir::config::AdaptiveCfg { improve_factor: 1.1, ..Default::default() }
}

/// Hand-built serving fixture (same shape as `tests/adaptive.rs`): a
/// two-platform split plus single-platform fallbacks with controlled
/// capacities, so the failover scenario forces a migration.
fn single(platform: usize, label: &str, lat: f64) -> CandidateMetrics {
    let mut memory = vec![0u64, 0];
    memory[platform] = 5_000_000;
    CandidateMetrics {
        positions: vec![if platform == 0 { 9 } else { 0 }],
        label: label.to_string(),
        latency_s: lat,
        energy_j: 1.0,
        throughput: 1.0 / lat,
        top1: 70.0,
        memory_bytes: memory,
        link_bytes: 0,
        partitions: 1,
        plan: vec![StagePlan {
            platform,
            latency_s: lat,
            energy_j: 1.0,
            out_bytes: 0,
            out_hops: 0,
            edges: Vec::new(),
            replicas: 1,
        }],
        assign: None,
        violation: 0.0,
        violations: Vec::new(),
        robustness: None,
    }
}

fn toy_exploration() -> Exploration {
    let split = CandidateMetrics {
        positions: vec![4],
        label: "split".into(),
        latency_s: 0.002,
        energy_j: 1.0,
        throughput: 1000.0,
        top1: 70.0,
        memory_bytes: vec![2_500_000, 2_500_000],
        link_bytes: 1460,
        partitions: 2,
        plan: vec![
            StagePlan {
                platform: 0,
                latency_s: 0.001,
                energy_j: 0.5,
                out_bytes: 1460,
                out_hops: 1,
                edges: vec![PlanEdge { to: Some(1), bytes: 1460, hops: 1 }],
                replicas: 1,
            },
            StagePlan {
                platform: 1,
                latency_s: 0.001,
                energy_j: 0.5,
                out_bytes: 0,
                out_hops: 0,
                edges: Vec::new(),
                replicas: 1,
            },
        ],
        assign: None,
        violation: 0.0,
        violations: Vec::new(),
        robustness: None,
    };
    Exploration {
        model: "toy".into(),
        candidates: vec![single(0, "all-on-A", 0.002), single(1, "all-on-B", 0.0025), split],
        pareto: vec![2],
        nsga_front: vec![2],
        favorite: Some(2),
        robust_favorite: None,
        timing: ExplorationTiming::default(),
    }
}

fn assert_fronts_equal(bare: &Exploration, obs: &Exploration, what: &str) {
    assert_eq!(bare.pareto, obs.pareto, "{what}: pareto set moved");
    assert_eq!(bare.nsga_front, obs.nsga_front, "{what}: NSGA front moved");
    assert_eq!(bare.favorite, obs.favorite, "{what}: favorite moved");
    assert_eq!(bare.candidates.len(), obs.candidates.len(), "{what}: candidate count moved");
    for (a, b) in bare.candidates.iter().zip(&obs.candidates) {
        assert_eq!(a.label, b.label, "{what}: label moved");
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{what}: latency bits moved");
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{what}: energy bits moved");
        assert_eq!(
            a.throughput.to_bits(),
            b.throughput.to_bits(),
            "{what}: throughput bits moved"
        );
    }
}

#[test]
fn exploration_front_bit_identical_with_obs_on_across_jobs() {
    let g = zoo::tiny_cnn(10);
    let bare = quick_sys();
    for jobs in [1usize, 4] {
        let chain_off = ExploreRequest::chain().jobs(jobs).run(&g, &bare);
        let dag_off = ExploreRequest::dag().jobs(jobs).run(&g, &bare);
        // Fresh live registry per run: recording must not perturb.
        let chain_on = ExploreRequest::chain().jobs(jobs).run(&g, &obs_sys());
        let dag_on = ExploreRequest::dag().jobs(jobs).run(&g, &obs_sys());
        assert_fronts_equal(&chain_off, &chain_on, &format!("chain jobs={jobs}"));
        assert_fronts_equal(&dag_off, &dag_on, &format!("dag jobs={jobs}"));
    }
    // The instrumented run actually recorded something (the contract is
    // "inert", not "absent").
    let sys = obs_sys();
    let _ = ExploreRequest::chain().run(&g, &sys);
    let reg = sys.obs.registry().unwrap();
    assert!(reg.counter("explorer.requests").get() >= 1);
    assert!(reg.span_count() > 0, "no spans recorded by an instrumented exploration");
}

#[test]
fn sim_and_adaptive_fingerprints_bit_identical_with_obs_on() {
    let ex = toy_exploration();
    let sc = Scenario::failover(12_000, 300.0);
    let cfg = SimCfg { seed: 7, ..Default::default() };

    // Static engine: instrumented run, same fingerprint.
    let dep = Deployment::from_candidate(&ex.candidates[2], &quick_sys());
    let reg = Arc::new(Registry::new());
    let bare = simulate(&dep, &cfg, &sc);
    let inst = simulate_obs(&dep, &cfg, &sc, Some(&reg));
    assert_eq!(bare.fingerprint(), inst.fingerprint(), "simulate_obs moved the fingerprint");
    assert!(reg.counter("sim.stage00.batches").get() > 0, "engine counters stayed silent");
    assert!(reg.span_count() > 0, "engine spans stayed silent");

    // Adaptive comparison: obs-on vs obs-off, jobs 1 vs 4.
    let off = compare_adaptive(&ex, &quick_sys(), &sc, &cfg, &acfg(), 1);
    for jobs in [1usize, 4] {
        let sys_on = obs_sys();
        let on = compare_adaptive(&ex, &sys_on, &sc, &cfg, &acfg(), jobs);
        assert_eq!(
            off.static_report.fingerprint(),
            on.static_report.fingerprint(),
            "obs moved the static baseline (jobs={jobs})"
        );
        assert_eq!(
            off.adaptive.fingerprint(),
            on.adaptive.fingerprint(),
            "obs moved the adaptive run (jobs={jobs})"
        );
        assert_eq!(
            off.oracle.fingerprint(),
            on.oracle.fingerprint(),
            "obs moved the oracle run (jobs={jobs})"
        );
    }

    // Front evaluation: obs-on vs obs-off, jobs 1 vs 4.
    let ranked_off = evaluate_front(&ex, &quick_sys(), &sc, &cfg, 1);
    for jobs in [1usize, 4] {
        let ranked_on = evaluate_front(&ex, &obs_sys(), &sc, &cfg, jobs);
        assert_eq!(ranked_off, ranked_on, "obs moved the ranking (jobs={jobs})");
    }
}

#[test]
fn trace_export_is_valid_json_with_monotone_lane_timestamps() {
    use partir::util::json::Json;
    let ex = toy_exploration();
    let sys = obs_sys();
    let sc = Scenario::failover(12_000, 300.0);
    let cfg = SimCfg { seed: 7, ..Default::default() };
    let _ = compare_adaptive(&ex, &sys, &sc, &cfg, &acfg(), 2);
    let reg = sys.obs.registry().unwrap();
    let doc = Json::parse(&chrome_trace(reg).dump()).expect("trace is not valid JSON");
    let events = doc.get("traceEvents").as_arr().expect("no traceEvents array");
    assert!(events.len() > 2, "trace holds only metadata");
    // Per-(pid, tid) lane timestamps must be monotone in document
    // order — that is what makes the Perfetto view readable.
    let mut last: std::collections::BTreeMap<(u64, u64), f64> = Default::default();
    let mut x_events = 0usize;
    for e in events {
        match e.get("ph").as_str() {
            Some("X") => {}
            Some("M") => continue,
            other => panic!("unexpected phase {other:?}"),
        }
        x_events += 1;
        let key = (e.get("pid").as_u64().unwrap(), e.get("tid").as_u64().unwrap());
        let ts = e.get("ts").as_f64().unwrap();
        assert!(ts >= 0.0 && e.get("dur").as_f64().unwrap() >= 0.0);
        if let Some(prev) = last.insert(key, ts) {
            assert!(ts >= prev, "lane {key:?} went backwards: {prev} -> {ts}");
        }
    }
    assert!(x_events > 0, "no span events exported");
}

#[test]
fn metrics_snapshot_csv_roundtrip_is_exact() {
    let reg = Registry::new();
    reg.counter("a.hits").add(41);
    reg.counter("a.misses").inc();
    reg.gauge("b.depth").set(17);
    let h = reg.histogram("c.fill");
    for v in [0u64, 1, 2, 3, 1024, u64::MAX] {
        h.observe(v);
    }
    let snap = reg.snapshot();
    let text = snap.to_csv().to_string();
    let back = Snapshot::from_csv(&text).expect("snapshot CSV failed to parse");
    assert_eq!(snap.rows, back.rows, "CSV round-trip lost rows");
    assert!(snap.rows.iter().any(|r| r.name == "a.hits" && r.value == 41));
    assert!(snap.rows.iter().any(|r| r.name == "c.fill" && r.kind == "hist_count" && r.value == 6));
}

#[test]
fn failover_trace_contains_controller_migration_span() {
    let ex = toy_exploration();
    let sys = obs_sys();
    let sc = Scenario::failover(24_000, 300.0);
    let cfg = SimCfg { seed: 7, ..Default::default() };
    let cmp = compare_adaptive(&ex, &sys, &sc, &cfg, &acfg(), 1);
    assert!(!cmp.adaptive.migrations.is_empty(), "failover preset produced no migration");
    let reg = sys.obs.registry().unwrap();
    assert_eq!(reg.counter("adaptive.migrations").get(), cmp.adaptive.migrations.len() as u64);
    let doc = chrome_trace(reg);
    let events = doc.get("traceEvents").as_arr().unwrap();
    // The migration window rides the virtual-clock track (pid 2), on
    // the reserved controller lane 0, named after the cutover.
    let migration_spans: Vec<_> = events
        .iter()
        .filter(|e| {
            e.get("ph").as_str() == Some("X")
                && e.get("pid").as_u64() == Some(2)
                && e.get("tid").as_u64() == Some(0)
                && e.get("name").as_str().map_or(false, |n| n.starts_with("migrate "))
        })
        .collect();
    assert_eq!(
        migration_spans.len(),
        cmp.adaptive.migrations.len(),
        "one controller span per migration"
    );
    for s in &migration_spans {
        assert!(s.get("dur").as_f64().unwrap() > 0.0, "migration span has no width");
        assert!(s.get("name").as_str().unwrap().contains("->"));
    }
}
