//! Integration tests across the explorer pipeline: graph → memory/link
//! filters → accuracy → HW eval → NSGA-II → Pareto/favorite, on real zoo
//! models with the paper's system configs.

use partir::config::{Metric, SystemConfig};
use partir::explorer::ExploreRequest;
use partir::graph::topo::{topo_sort, TieBreak};
use partir::link::LinkModel;
use partir::zoo;

fn quick_sys() -> SystemConfig {
    let mut sys = SystemConfig::paper_two_platform();
    sys.search.victory = 15;
    sys.search.max_samples = 150;
    sys
}

#[test]
fn all_paper_models_explore_cleanly() {
    let sys = quick_sys();
    for name in zoo::PAPER_MODELS {
        let g = zoo::build(name).unwrap();
        let ex = ExploreRequest::chain().run(&g, &sys);
        assert!(!ex.pareto.is_empty(), "{name}: empty Pareto front");
        assert!(ex.favorite.is_some(), "{name}: no favorite");
        // Single-platform references present exactly once each.
        let singles: Vec<&str> = ex
            .candidates
            .iter()
            .filter(|c| c.partitions == 1)
            .map(|c| c.label.as_str())
            .collect();
        assert_eq!(singles.iter().filter(|l| **l == "all-on-A").count(), 1, "{name}");
        assert_eq!(singles.iter().filter(|l| **l == "all-on-B").count(), 1, "{name}");
        // Metrics are finite and positive everywhere.
        for c in &ex.candidates {
            assert!(c.latency_s.is_finite() && c.latency_s > 0.0, "{name}/{}", c.label);
            assert!(c.energy_j.is_finite() && c.energy_j > 0.0, "{name}/{}", c.label);
            assert!(c.throughput.is_finite() && c.throughput > 0.0, "{name}/{}", c.label);
            assert!((0.0..=100.0).contains(&c.top1), "{name}/{}", c.label);
        }
    }
}

#[test]
fn parallel_exploration_bit_identical_to_serial() {
    // Acceptance gate for the multi-core DSE: `--jobs 1` and `--jobs 4`
    // must produce byte-identical Pareto sets, favorites and metrics.
    for name in ["tiny_cnn", "squeezenet1_1"] {
        let g = zoo::build(name).unwrap();
        let mut serial = quick_sys();
        serial.jobs = 1;
        let mut par = quick_sys();
        par.jobs = 4;
        let a = ExploreRequest::chain().run(&g, &serial);
        let b = ExploreRequest::chain().run(&g, &par);
        assert_eq!(a.pareto, b.pareto, "{name}: Pareto sets diverge");
        assert_eq!(a.nsga_front, b.nsga_front, "{name}: NSGA fronts diverge");
        assert_eq!(a.favorite, b.favorite, "{name}: favorites diverge");
        assert_eq!(a.candidates.len(), b.candidates.len(), "{name}");
        for (x, y) in a.candidates.iter().zip(&b.candidates) {
            assert_eq!(x.positions, y.positions, "{name}/{}", x.label);
            assert_eq!(x.label, y.label, "{name}");
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits(), "{name}/{}", x.label);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{name}/{}", x.label);
            assert_eq!(x.throughput.to_bits(), y.throughput.to_bits(), "{name}/{}", x.label);
            assert_eq!(x.top1.to_bits(), y.top1.to_bits(), "{name}/{}", x.label);
            assert_eq!(x.memory_bytes, y.memory_bytes, "{name}/{}", x.label);
            assert_eq!(x.link_bytes, y.link_bytes, "{name}/{}", x.label);
            assert_eq!(x.partitions, y.partitions, "{name}/{}", x.label);
        }
    }
}

#[test]
fn pareto_front_is_internally_consistent() {
    let g = zoo::googlenet(1000);
    let sys = quick_sys();
    let ex = ExploreRequest::chain().run(&g, &sys);
    // No front member dominates another on the configured metrics.
    for &i in &ex.pareto {
        for &j in &ex.pareto {
            if i == j {
                continue;
            }
            let a = &ex.candidates[i];
            let b = &ex.candidates[j];
            let dominates = sys
                .pareto_metrics
                .iter()
                .all(|&m| a.objective(m) <= b.objective(m))
                && sys
                    .pareto_metrics
                    .iter()
                    .any(|&m| a.objective(m) < b.objective(m));
            assert!(!dominates, "{} dominates {} on the front", a.label, b.label);
        }
    }
}

#[test]
fn accuracy_monotone_in_cut_position_for_16_8_system() {
    // EYR is 16-bit (platform A): later cuts -> more 16-bit MACs ->
    // monotonically non-decreasing top-1 (paper Fig 2c/f guideline).
    let g = zoo::efficientnet_b0(1000);
    let sys = quick_sys();
    let ex = ExploreRequest::chain().run(&g, &sys);
    let mut by_pos: Vec<(usize, f64)> = ex
        .candidates
        .iter()
        .filter(|c| c.partitions == 2)
        .map(|c| (c.positions[0], c.top1))
        .collect();
    by_pos.sort_by_key(|&(p, _)| p);
    for w in by_pos.windows(2) {
        assert!(
            w[1].1 >= w[0].1 - 1e-9,
            "top1 dropped with a later cut: {:?} -> {:?}",
            w[0],
            w[1]
        );
    }
}

#[test]
fn slow_link_pushes_optimum_to_single_platform() {
    // Ablation: with a 1 Mbit/s link, transmitting any feature map is
    // prohibitively slow; the latency-favorite must be single-platform.
    let g = zoo::squeezenet1_1(1000);
    let mut sys = quick_sys();
    sys.link = LinkModel { bandwidth_bps: 1e6, ..LinkModel::gigabit_ethernet() };
    sys.favorite.weights = vec![(Metric::Latency, 1.0)];
    let ex = ExploreRequest::chain().run(&g, &sys);
    let fav = ex.favorite_metrics().unwrap();
    assert_eq!(fav.partitions, 1, "favorite {} should be single-platform", fav.label);
}

#[test]
fn ideal_link_makes_pipelining_dominate_throughput() {
    let g = zoo::resnet50(1000);
    let mut sys = quick_sys();
    sys.link = LinkModel::ideal();
    let ex = ExploreRequest::chain().run(&g, &sys);
    let best = ex
        .candidates
        .iter()
        .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
        .unwrap();
    assert!(best.partitions == 2, "ideal link should favour a split, got {}", best.label);
}

#[test]
fn throughput_never_exceeds_sum_of_platform_rates() {
    // Def 4 sanity: min() of stage rates cannot exceed the sum of the
    // two single-platform rates.
    let g = zoo::vgg16(1000);
    let sys = quick_sys();
    let ex = ExploreRequest::chain().run(&g, &sys);
    let sum: f64 = ex
        .candidates
        .iter()
        .filter(|c| c.partitions == 1)
        .map(|c| c.throughput)
        .sum();
    for c in ex.candidates.iter().filter(|c| c.partitions == 2) {
        assert!(
            c.throughput <= sum * 1.0001,
            "{} throughput {} > sum {}",
            c.label,
            c.throughput,
            sum
        );
    }
}

#[test]
fn memory_reported_matches_standalone_estimator() {
    let g = zoo::squeezenet1_1(1000);
    let sys = quick_sys();
    let ex = ExploreRequest::chain().run(&g, &sys);
    let order = topo_sort(&g, TieBreak::Deterministic);
    for c in ex.candidates.iter().filter(|c| c.partitions == 2) {
        let p = c.positions[0];
        let ma = partir::memory::segment_memory_bytes(&g, &order, 0..p + 1, 16);
        assert_eq!(c.memory_bytes[0], ma, "{}", c.label);
    }
}

#[test]
fn four_platform_chain_respects_memory_caps() {
    let g = zoo::resnet50(1000);
    let mut sys = SystemConfig::paper_four_platform();
    sys.search.victory = 10;
    sys.search.max_samples = 100;
    // Tight caps on the two EYR platforms force weight mass to C/D.
    sys.platforms[0].memory_bytes = 4 << 20;
    sys.platforms[1].memory_bytes = 4 << 20;
    let ex = ExploreRequest::chain().run(&g, &sys);
    for &i in &ex.pareto {
        let c = &ex.candidates[i];
        assert!(c.feasible());
        assert!(c.memory_bytes[0] <= 4 << 20, "{}", c.label);
        assert!(c.memory_bytes[1] <= 4 << 20, "{}", c.label);
    }
}

#[test]
fn qat_flag_raises_top1() {
    let g = zoo::efficientnet_b0(1000);
    let mut sys = quick_sys();
    let without = ExploreRequest::chain().run(&g, &sys);
    sys.qat = true;
    let with = ExploreRequest::chain().run(&g, &sys);
    // Same candidate order (deterministic): compare pointwise.
    for (a, b) in without.candidates.iter().zip(&with.candidates) {
        assert!(b.top1 >= a.top1, "{}: QAT lowered top1", a.label);
    }
}

#[test]
fn config_round_trip_drives_exploration() {
    // A custom TOML config (different link + constraints) loads and
    // changes the outcome vs the default.
    let toml = r#"
[[platforms]]
name = "A"
accelerator = "EYR"
memory_mib = 64

[[platforms]]
name = "B"
accelerator = "SMB"
memory_mib = 64

[link]
bandwidth_mbps = 10.0
base_latency_us = 2000.0

[constraints]
min_top1 = 50.0
"#;
    let doc = partir::util::tomlite::parse(toml).unwrap();
    let mut sys = SystemConfig::from_json(&doc).unwrap();
    sys.search.victory = 10;
    sys.search.max_samples = 100;
    let g = zoo::squeezenet1_1(1000);
    let slow = ExploreRequest::chain().run(&g, &sys);
    let fast_ex = ExploreRequest::chain().run(&g, &quick_sys());
    // The 10 Mbit/s link must raise every two-partition latency.
    let avg = |ex: &partir::explorer::Exploration| {
        let xs: Vec<f64> = ex
            .candidates
            .iter()
            .filter(|c| c.partitions == 2)
            .map(|c| c.latency_s)
            .collect();
        xs.iter().sum::<f64>() / xs.len() as f64
    };
    assert!(avg(&slow) > 2.0 * avg(&fast_ex));
}
