//! Adaptive-serving acceptance tests: the runtime re-partitioning
//! controller must (a) stay invisible when nothing goes wrong —
//! bit-identical to the static simulator, (b) strictly beat the static
//! favorite when the scenario degrades or a node dies, (c) stay within
//! a bounded goodput gap of the schedule-aware oracle, and (d) be
//! bit-identical across `--jobs` values.
//!
//! The exploration is hand-built (no mapper), mirroring the fixture in
//! `sim/evaluate.rs`: a two-stage split across both platforms plus the
//! two single-platform fallbacks, with controlled capacities so every
//! win below is forced by construction:
//!
//! * `split`    — 1 ms/stage on platforms 0 and 1 → ~1000 req/s
//! * `all-on-A` — 2 ms on platform 0             →  ~500 req/s
//! * `all-on-B` — 2.5 ms on platform 1           →  ~400 req/s

use partir::config::{AdaptiveCfg, SystemConfig};
use partir::explorer::{CandidateMetrics, Exploration, ExplorationTiming, PlanEdge, StagePlan};
use partir::sim::{candidate_pool, compare_adaptive, Scenario, SimCfg};

fn single(platform: usize, label: &str, lat: f64) -> CandidateMetrics {
    let mut memory = vec![0u64, 0];
    memory[platform] = 5_000_000;
    CandidateMetrics {
        positions: vec![if platform == 0 { 9 } else { 0 }],
        label: label.to_string(),
        latency_s: lat,
        energy_j: 1.0,
        throughput: 1.0 / lat,
        top1: 70.0,
        memory_bytes: memory,
        link_bytes: 0,
        partitions: 1,
        plan: vec![StagePlan {
            platform,
            latency_s: lat,
            energy_j: 1.0,
            out_bytes: 0,
            out_hops: 0,
            edges: Vec::new(),
            replicas: 1,
        }],
        assign: None,
        violation: 0.0,
        violations: Vec::new(),
        robustness: None,
    }
}

fn toy_exploration() -> Exploration {
    let split = CandidateMetrics {
        positions: vec![4],
        label: "split".into(),
        latency_s: 0.002,
        energy_j: 1.0,
        throughput: 1000.0,
        top1: 70.0,
        memory_bytes: vec![2_500_000, 2_500_000],
        link_bytes: 1460,
        partitions: 2,
        plan: vec![
            StagePlan {
                platform: 0,
                latency_s: 0.001,
                energy_j: 0.5,
                out_bytes: 1460,
                out_hops: 1,
                edges: vec![PlanEdge { to: Some(1), bytes: 1460, hops: 1 }],
                replicas: 1,
            },
            StagePlan {
                platform: 1,
                latency_s: 0.001,
                energy_j: 0.5,
                out_bytes: 0,
                out_hops: 0,
                edges: Vec::new(),
                replicas: 1,
            },
        ],
        assign: None,
        violation: 0.0,
        violations: Vec::new(),
        robustness: None,
    };
    Exploration {
        model: "toy".into(),
        candidates: vec![single(0, "all-on-A", 0.002), single(1, "all-on-B", 0.0025), split],
        pareto: vec![2],
        nsga_front: vec![2],
        favorite: Some(2),
        robust_favorite: None,
        timing: ExplorationTiming::default(),
    }
}

fn acfg() -> AdaptiveCfg {
    // Slightly laxer improvement bar than the default so the 3x
    // degraded split (score ~333/s) vs all-on-B (400/s) clears it with
    // margin; everything else matches the shipping defaults.
    AdaptiveCfg { improve_factor: 1.1, ..AdaptiveCfg::default() }
}

fn sim_cfg() -> SimCfg {
    SimCfg { seed: 7, ..Default::default() }
}

#[test]
fn no_fault_adaptive_never_migrates_and_matches_static_fingerprint() {
    let ex = toy_exploration();
    let sys = SystemConfig::paper_two_platform();
    // Flat traffic well under the favorite's capacity: the controller
    // must observe healthy epochs throughout and never move.
    let sc = Scenario::steady(8_000, 300.0);
    let cmp = compare_adaptive(&ex, &sys, &sc, &sim_cfg(), &acfg(), 1);
    assert!(cmp.adaptive.epochs > 0, "controller observed no epochs");
    assert!(
        cmp.adaptive.migrations.is_empty(),
        "migrated without faults: {:?}",
        cmp.adaptive.migrations
    );
    assert!(cmp.oracle.migrations.is_empty(), "oracle migrated without faults");
    assert_eq!(cmp.adaptive.total_migration_ns, 0);
    // The zero-migration adaptive run is ONE engine regime and must be
    // bit-identical to the static simulation of the same candidate.
    assert_eq!(
        cmp.adaptive.report.fingerprint(),
        cmp.static_report.fingerprint(),
        "adaptive epoch stepping perturbed the event stream"
    );
    assert_eq!(cmp.adaptive.start_candidate, cmp.adaptive.final_candidate);
}

#[test]
fn adaptive_beats_static_favorite_under_degraded_preset() {
    let ex = toy_exploration();
    let sys = SystemConfig::paper_two_platform();
    // 380 req/s: under the split's nominal 1000/s, but over its ~333/s
    // capacity while platform 0 runs 3x slow — the static favorite
    // sheds load for the whole window; the controller should detect
    // the drops and fail over to all-on-B (400/s).
    let sc = Scenario::degraded(24_000, 380.0);
    let cmp = compare_adaptive(&ex, &sys, &sc, &sim_cfg(), &acfg(), 1);
    assert!(
        !cmp.adaptive.migrations.is_empty(),
        "controller never reacted to the degradation"
    );
    assert!(
        cmp.adaptive.report.goodput > cmp.static_report.goodput,
        "adaptive {} <= static {}",
        cmp.adaptive.report.goodput,
        cmp.static_report.goodput
    );
    assert!(cmp.adaptive.report.dropped < cmp.static_report.dropped);
}

#[test]
fn adaptive_beats_static_favorite_under_failover_preset() {
    let ex = toy_exploration();
    let sys = SystemConfig::paper_two_platform();
    // Node loss on platform 0 for 30% of the trace: the static split
    // drops everything it is offered during the window; the controller
    // must fail over to the surviving single-node plan and back-fill.
    let sc = Scenario::failover(24_000, 300.0);
    let cmp = compare_adaptive(&ex, &sys, &sc, &sim_cfg(), &acfg(), 1);
    assert!(!cmp.adaptive.migrations.is_empty(), "controller never failed over");
    let first = &cmp.adaptive.migrations[0];
    assert_eq!(
        cmp.pool[first.to].label,
        "all-on-B",
        "failed over to a plan touching the dead platform"
    );
    // Migrations pay real, nonzero modeled cost over the link.
    for m in &cmp.adaptive.migrations {
        assert!(m.cost_ns > 0, "free cutover: {m:?}");
        assert!(m.weight_bytes + m.activation_bytes > 0, "no bytes shipped: {m:?}");
    }
    assert!(cmp.adaptive.total_migration_ns > 0);
    assert!(cmp.adaptive.total_migration_bytes > 0);
    assert!(
        cmp.adaptive.report.goodput > cmp.static_report.goodput,
        "adaptive {} <= static {}",
        cmp.adaptive.report.goodput,
        cmp.static_report.goodput
    );
    // The render paths must stay panic-free and mention the cutover.
    let rendered = cmp.render();
    assert!(rendered.contains("all-on-B"));
    assert!(!rendered.contains("NaN"));
}

#[test]
fn hysteresis_gap_to_oracle_is_reported_and_bounded() {
    let ex = toy_exploration();
    let sys = SystemConfig::paper_two_platform();
    let sc = Scenario::failover(24_000, 300.0);
    let cmp = compare_adaptive(&ex, &sys, &sc, &sim_cfg(), &acfg(), 1);
    let gap = cmp.gap();
    assert!(gap.is_finite() && gap >= 0.0, "bad gap {gap}");
    // The reactive controller loses only the detection window (a few
    // control epochs) to the schedule-aware oracle.
    assert!(
        cmp.adaptive.report.goodput >= 0.6 * cmp.oracle.report.goodput,
        "hysteresis goodput {} too far below oracle {}",
        cmp.adaptive.report.goodput,
        cmp.oracle.report.goodput
    );
    assert!(gap <= 0.4, "gap {gap} out of bounds");
}

#[test]
fn adaptive_determinism_jobs_identity() {
    let ex = toy_exploration();
    let sys = SystemConfig::paper_two_platform();
    // The failover scenario exercises the full multi-regime path:
    // migrations, carryover, and post-recovery epochs.
    let sc = Scenario::failover(12_000, 300.0);
    let a = compare_adaptive(&ex, &sys, &sc, &sim_cfg(), &acfg(), 1);
    let b = compare_adaptive(&ex, &sys, &sc, &sim_cfg(), &acfg(), 4);
    assert_eq!(
        a.static_report.fingerprint(),
        b.static_report.fingerprint(),
        "--jobs changed the static baseline"
    );
    assert_eq!(
        a.adaptive.fingerprint(),
        b.adaptive.fingerprint(),
        "--jobs changed the adaptive run"
    );
    assert_eq!(
        a.oracle.fingerprint(),
        b.oracle.fingerprint(),
        "--jobs changed the oracle run"
    );
    // Repeat runs are bit-identical too (no hidden global state).
    let c = compare_adaptive(&ex, &sys, &sc, &sim_cfg(), &acfg(), 1);
    assert_eq!(a.adaptive.fingerprint(), c.adaptive.fingerprint());
}

#[test]
fn candidate_pool_surfaces_plans_and_platform_sets() {
    let ex = toy_exploration();
    let pool = candidate_pool(&ex);
    // Pareto front + two feasible singles, in candidate order.
    assert_eq!(pool.len(), 3);
    assert_eq!(pool[0].label, "all-on-A");
    assert_eq!(pool[0].platforms, vec![0]);
    assert_eq!(pool[1].label, "all-on-B");
    assert_eq!(pool[1].platforms, vec![1]);
    assert_eq!(pool[2].label, "split");
    assert_eq!(pool[2].platforms, vec![0, 1]);
    for p in &pool {
        assert!(!p.stages.is_empty());
        assert!(p.throughput > 0.0);
        assert!(p.memory_bytes.iter().sum::<u64>() > 0);
    }
}
