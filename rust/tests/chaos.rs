//! Fault-ensemble robustness gate (PR 10 acceptance): the chaos
//! harness must be a pure function of `(exploration, base scenario,
//! chaos config, seed)` — bit-identical across `--jobs` values and
//! reruns — and its scoring must degrade gracefully at the edges: an
//! empty ensemble reduces every aggregate to the plain simulation, a
//! fault-free member recovers in zero epochs with the exact baseline
//! fingerprint (the epoch-stepped engine replays the one-shot event
//! stream), CVaR tightens monotonically in `q`, and re-ranking is a
//! permutation of the serving set — it never drops a Pareto member.

use partir::config::{AdaptiveCfg, ChaosCfg, SystemConfig};
use partir::explorer::{Exploration, ExploreRequest};
use partir::sim::{
    chaos_base_scenario, compare_adaptive_ensemble, score_robustness, score_robustness_with,
    simulate, Deployment, EnsembleMember, FaultEnsemble, SimCfg,
};
use partir::util::hash::Fnv64;
use partir::zoo;

fn quick_sys() -> SystemConfig {
    let mut sys = SystemConfig::paper_two_platform();
    sys.search.victory = 10;
    sys.search.max_samples = 100;
    sys
}

fn quick_ex(sys: &SystemConfig) -> Exploration {
    ExploreRequest::chain().run(&zoo::tiny_cnn(10), sys)
}

/// Small ensembles over short traces: the gate is about determinism and
/// ordering, not statistical power.
fn ccfg(ensemble: usize) -> ChaosCfg {
    ChaosCfg { ensemble, requests: 3000, ..ChaosCfg::default() }
}

#[test]
fn scoring_is_bit_identical_across_jobs_and_reruns() {
    let sys = quick_sys();
    let ex = quick_ex(&sys);
    let base = chaos_base_scenario(&ex, &ccfg(6));
    let cfg = SimCfg { seed: 11, ..Default::default() };
    // Generation first: per-member streams make the expansion a pure
    // function of (base, ccfg, platforms, seed).
    let a = FaultEnsemble::generate(&base, &ccfg(6), sys.platforms.len(), cfg.seed);
    let b = FaultEnsemble::generate(&base, &ccfg(6), sys.platforms.len(), cfg.seed);
    assert_eq!(a, b, "ensemble generation must be rerun-stable");
    // Then the full report, across the worker grid and a rerun.
    let fps: Vec<u64> = [1usize, 2, 4]
        .iter()
        .map(|&j| score_robustness(&ex, &sys, &base, &cfg, &ccfg(6), j).fingerprint())
        .collect();
    assert_eq!(fps[0], fps[1], "jobs=2 moved the robustness report");
    assert_eq!(fps[0], fps[2], "jobs=4 moved the robustness report");
    let again = score_robustness(&ex, &sys, &base, &cfg, &ccfg(6), 1).fingerprint();
    assert_eq!(fps[0], again, "rerun moved the robustness report");
}

#[test]
fn empty_ensemble_reduces_to_the_plain_simulation() {
    let sys = quick_sys();
    let ex = quick_ex(&sys);
    let base = chaos_base_scenario(&ex, &ccfg(0));
    let cfg = SimCfg { seed: 3, ..Default::default() };
    let rep = score_robustness(&ex, &sys, &base, &cfg, &ccfg(0), 2);
    assert_eq!(rep.scores.len(), ex.serving_candidates().len());
    assert!(rep.robust_favorite.is_some(), "the no-op report still picks a favorite");
    for s in &rep.scores {
        // Every aggregate collapses onto the fault-free baseline …
        assert_eq!(s.worst_goodput.to_bits(), s.baseline_goodput.to_bits());
        assert_eq!(s.mean_goodput.to_bits(), s.baseline_goodput.to_bits());
        assert_eq!(s.cvar_goodput.to_bits(), s.baseline_goodput.to_bits());
        assert_eq!(s.ttr_epochs, 0);
        assert!(s.members.is_empty());
        // … and the baseline IS the plain simulation, bit for bit.
        let dep = Deployment::from_candidate(&ex.candidates[s.candidate], &sys);
        let plain = simulate(&dep, &cfg, &base);
        assert_eq!(
            s.baseline_fingerprint,
            plain.fingerprint(),
            "candidate '{}' baseline diverged from simulate()",
            s.label
        );
    }
}

#[test]
fn fault_free_members_recover_in_zero_epochs_with_the_baseline_fingerprint() {
    let sys = quick_sys();
    let ex = quick_ex(&sys);
    let base = chaos_base_scenario(&ex, &ccfg(0));
    let cfg = SimCfg { seed: 5, ..Default::default() };
    // One hand-built member with no fault windows at all: TTR is 0 by
    // definition, and the epoch-stepped run must replay the one-shot
    // event stream exactly (the engine's chunked-stepping identity).
    let ensemble = FaultEnsemble {
        members: vec![EnsembleMember { id: 0, label: "clean".into(), scenario: base.clone() }],
    };
    let rep = score_robustness_with(&ex, &sys, &base, &ensemble, &cfg, &ccfg(0), 2);
    for s in &rep.scores {
        assert_eq!(s.ttr_epochs, 0, "fault-free member must not need recovery");
        for m in &s.members {
            assert_eq!(m.recovery_epochs, 0);
            assert_eq!(m.goodput.to_bits(), s.baseline_goodput.to_bits());
            assert_eq!(
                m.fingerprint, s.baseline_fingerprint,
                "epoch-stepped member run diverged from the one-shot baseline"
            );
        }
    }
}

#[test]
fn cvar_is_monotone_in_q_and_bounded_by_worst_and_mean() {
    let sys = quick_sys();
    let ex = quick_ex(&sys);
    let base = chaos_base_scenario(&ex, &ccfg(8));
    let cfg = SimCfg { seed: 17, ..Default::default() };
    let at_q = |q: f64| {
        let c = ChaosCfg { cvar_q: q, ..ccfg(8) };
        score_robustness(&ex, &sys, &base, &cfg, &c, 2)
    };
    let (q25, q50, q100) = (at_q(0.25), at_q(0.5), at_q(1.0));
    for s in &q25.scores {
        assert!(s.worst_goodput <= s.cvar_goodput, "{}: worst above CVaR", s.label);
        assert!(s.cvar_goodput <= s.mean_goodput, "{}: CVaR above mean", s.label);
        let s50 = q50.score_of(s.candidate).unwrap();
        let s100 = q100.score_of(s.candidate).unwrap();
        // Averaging over a larger tail can only raise the estimate.
        assert!(s.cvar_goodput <= s50.cvar_goodput, "{}: CVaR fell from q=.25 to .5", s.label);
        assert!(s50.cvar_goodput <= s100.cvar_goodput, "{}: CVaR fell from q=.5 to 1", s.label);
        // CVaR over the whole ensemble IS the mean.
        assert_eq!(s100.cvar_goodput.to_bits(), s100.mean_goodput.to_bits());
        // q only changes the aggregation, never the member runs.
        assert_eq!(s.worst_goodput.to_bits(), s100.worst_goodput.to_bits());
        assert_eq!(s.ttr_epochs, s100.ttr_epochs);
    }
}

#[test]
fn chaos_request_reranks_without_dropping_serving_candidates() {
    let sys = quick_sys();
    let g = zoo::tiny_cnn(10);
    let plain = ExploreRequest::chain().run(&g, &sys);
    let chaotic = ExploreRequest::chain()
        .chaos(ChaosCfg { ensemble: 4, requests: 2000, ..ChaosCfg::default() })
        .run(&g, &sys);
    // The chaos stage is additive: fronts and favorite are untouched.
    assert_eq!(plain.pareto, chaotic.pareto);
    assert_eq!(plain.nsga_front, chaotic.nsga_front);
    assert_eq!(plain.favorite, chaotic.favorite);
    assert_eq!(plain.robust_favorite, None);
    // Re-ranking covers the full serving set — a permutation, not a
    // filter — so every Pareto member keeps a robustness score.
    let serving = chaotic.serving_candidates();
    let rf = chaotic.robust_favorite.expect("chaos run must surface a robust favorite");
    assert!(serving.contains(&rf), "robust favorite left the serving set");
    for &i in &serving {
        assert!(
            chaotic.candidates[i].robustness.is_some(),
            "serving candidate '{}' lost its score",
            chaotic.candidates[i].label
        );
    }
    for &p in &chaotic.pareto {
        assert!(serving.contains(&p), "Pareto member {p} dropped from the serving set");
    }
    for (i, c) in chaotic.candidates.iter().enumerate() {
        if !serving.contains(&i) {
            assert!(c.robustness.is_none(), "non-serving candidate '{}' scored", c.label);
        }
    }
}

#[test]
fn adaptive_ensemble_comparison_is_bit_identical_across_jobs() {
    let sys = quick_sys();
    let ex = quick_ex(&sys);
    let base = chaos_base_scenario(&ex, &ChaosCfg { requests: 4000, ..ChaosCfg::default() });
    let cfg = SimCfg { seed: 9, ..Default::default() };
    let ensemble = FaultEnsemble::generate(&base, &ccfg(4), sys.platforms.len(), cfg.seed);
    let acfg = AdaptiveCfg::default();
    let fp = |jobs: usize| {
        let cmps = compare_adaptive_ensemble(&ex, &sys, &ensemble, &cfg, &acfg, jobs);
        assert_eq!(cmps.len(), ensemble.members.len());
        let mut h = Fnv64::new();
        for c in &cmps {
            h.write_u64(c.static_report.fingerprint());
            h.write_u64(c.adaptive.fingerprint());
            h.write_u64(c.oracle.fingerprint());
        }
        h.finish()
    };
    let one = fp(1);
    assert_eq!(one, fp(2), "jobs=2 moved the adaptive ensemble comparison");
    assert_eq!(one, fp(4), "jobs=4 moved the adaptive ensemble comparison");
}
