//! Integration: load the real AOT artifacts (built by `make artifacts`)
//! and execute them on the PJRT CPU client — the python→rust bridge.
//!
//! Skipped (with a message) when artifacts have not been built, and
//! compiled only with the `xla` feature (the PJRT engine is gated so the
//! default build works on bare toolchains).
#![cfg(feature = "xla")]

use partir::coordinator::{run_pipeline, BatchPolicy, PipelineCfg, StageComputeSpec, StageSpec};
use partir::runtime::{evaluate_top1, Engine, Manifest};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn full_model_runs_and_classifies() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let meta = m.find("full", None, None, 1).expect("full_fp32_n1 artifact");
    let exe = engine.load(&dir, meta).unwrap();
    let ts = m.load_testset().unwrap();
    let out = exe.run(ts.image(0)).unwrap();
    assert_eq!(out.len(), m.classes);
    assert!(out.iter().all(|v| v.is_finite()));
}

#[test]
fn batched_artifact_matches_single() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let e1 = engine.load(&dir, m.find("full", None, None, 1).unwrap()).unwrap();
    let e8 = engine.load(&dir, m.find("full", None, None, 8).unwrap()).unwrap();
    let ts = m.load_testset().unwrap();
    // Run 3 images through the batch-8 artifact (padded) and singly.
    let mut flat = Vec::new();
    for i in 0..3 {
        flat.extend_from_slice(ts.image(i));
    }
    let batched = e8.run_padded(&flat, 3).unwrap();
    for i in 0..3 {
        let single = e1.run(ts.image(i)).unwrap();
        let b = &batched[i * m.classes..(i + 1) * m.classes];
        for (x, y) in single.iter().zip(b) {
            assert!((x - y).abs() < 1e-4, "batch mismatch at image {i}: {x} vs {y}");
        }
    }
}

#[test]
fn stage_composition_matches_full_model() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let full = engine.load(&dir, m.find("full", None, None, 1).unwrap()).unwrap();
    let ts = m.load_testset().unwrap();
    for bd in 1..=3 {
        let a = engine.load(&dir, m.find("stageA", None, Some(bd), 1).unwrap()).unwrap();
        let b = engine.load(&dir, m.find("stageB", None, Some(bd), 1).unwrap()).unwrap();
        let mid = a.run(ts.image(0)).unwrap();
        assert_eq!(mid.len(), m.boundaries[&bd].shape.iter().product::<usize>());
        let out = b.run(&mid).unwrap();
        let direct = full.run(ts.image(0)).unwrap();
        for (x, y) in out.iter().zip(&direct) {
            assert!((x - y).abs() < 1e-3, "boundary {bd}: {x} vs {y}");
        }
    }
}

#[test]
fn measured_top1_matches_build_accuracy() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let ts = m.load_testset().unwrap();
    let fp32 = engine.load(&dir, m.find("full", None, None, 8).unwrap()).unwrap();
    let acc = evaluate_top1(&fp32, &ts).unwrap();
    assert!(
        (acc - m.accuracy.fp32).abs() < 0.5,
        "rust-measured fp32 top1 {acc} != python {}",
        m.accuracy.fp32
    );
    // Quantized variants exist and stay within a few points of fp32.
    let q8 = engine.load(&dir, m.find("full", Some(8), None, 8).unwrap()).unwrap();
    let acc8 = evaluate_top1(&q8, &ts).unwrap();
    assert!(acc8 > 20.0, "q8 accuracy collapsed: {acc8}");
}

#[test]
fn mixed_precision_pipeline_over_simulated_link() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let bd = 2usize;
    let mid_elems: usize = m.boundaries[&bd].shape.iter().product();
    let stage_a = StageSpec {
        name: "A-eyr16".into(),
        compute: StageComputeSpec::Artifacts {
            dir: dir.clone(),
            metas: vec![
                m.find("stageA", Some(16), Some(bd), 1).unwrap().clone(),
                m.find("stageA", Some(16), Some(bd), 8).unwrap().clone(),
            ],
        },
        out_bytes_per_item: (mid_elems * 2) as u64, // 16-bit on the wire
    };
    let stage_b = StageSpec {
        name: "B-smb8".into(),
        compute: StageComputeSpec::Artifacts {
            dir: dir.clone(),
            metas: vec![
                m.find("stageB", Some(8), Some(bd), 1).unwrap().clone(),
                m.find("stageB", Some(8), Some(bd), 8).unwrap().clone(),
            ],
        },
        out_bytes_per_item: 0,
    };
    let ts = m.load_testset().unwrap();
    let n = 32.min(ts.count);
    let inputs: Vec<Vec<f32>> = (0..n).map(|i| ts.image(i).to_vec()).collect();
    let cfg = PipelineCfg {
        batch: BatchPolicy::new(8, Duration::from_millis(1)),
        ..Default::default()
    };
    let report = run_pipeline(vec![stage_a, stage_b], &cfg, inputs);
    assert_eq!(report.completed(), n);
    // Predictions should be mostly correct (quantized model, easy set).
    let correct = report
        .completions
        .iter()
        .filter(|c| c.prediction == Some(ts.labels[c.id as usize] as usize))
        .count();
    assert!(
        correct as f64 / n as f64 > 0.5,
        "pipeline top1 {correct}/{n} too low"
    );
    assert!(report.stages[0].link > Duration::ZERO, "link not simulated");
}
