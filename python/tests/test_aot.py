"""AOT bridge tests: HLO text emission and manifest contract.

The full `python -m compile.aot` run (training included) is exercised by
`make artifacts`; these tests cover the export machinery itself on
untrained parameters so they stay fast.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


def test_to_hlo_text_basic():
    def fn(x):
        return (jnp.tanh(x) * 2.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[4,4]" in text


def test_export_fn_writes_file(tmp_path, params):
    fn = aot.segment_fn(params, 0, 1, None, None)
    path = tmp_path / "seg.hlo.txt"
    size = aot.export_fn(fn, jax.ShapeDtypeStruct((1, *model.INPUT_SHAPE), jnp.float32), str(path))
    assert size > 1000
    text = path.read_text()
    assert "ENTRY" in text
    # Weights are baked in as constants: the entry computation takes the
    # input tensor only. (Nested reduce/fusion regions have their own
    # parameter numbering, so check the entry layout signature.)
    assert "entry_computation_layout={(f32[1,3,32,32]{3,2,1,0})->" in text


def test_segment_fn_output_shape(params):
    fn = aot.segment_fn(params, 0, 2, None, None)
    x = jnp.zeros((2, *model.INPUT_SHAPE))
    (y,) = fn(x)
    assert y.shape == (2, *model.BOUNDARY_SHAPES[2])


def test_segment_fn_quantized(params):
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, *model.INPUT_SHAPE)).astype(np.float32))
    scales = model.calibrate(params, x, 8)
    fn = aot.segment_fn(params, 0, model.NUM_BLOCKS, 8, scales)
    (y,) = fn(x)
    yr = model.forward(params, x, bits=8, scales=scales)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)


def test_self_check_passes_on_consistent_params(params):
    data = model.make_dataset(16, 8, seed=2)[0]
    scales = model.calibrate(params, data[0][:8], 8)
    aot.self_check(params, scales, data)


def test_manifest_exists_after_make_artifacts():
    """If `make artifacts` ran, its manifest must satisfy the contract
    the Rust runtime depends on."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built yet")
    with open(path) as f:
        m = json.load(f)
    assert m["model"] == "tiny_cnn"
    assert m["classes"] == model.NUM_CLASSES
    assert set(m["boundaries"]) == {"1", "2", "3"}
    roles = {a["role"] for a in m["artifacts"]}
    assert roles == {"full", "stageA", "stageB"}
    for a in m["artifacts"]:
        f = os.path.join(os.path.dirname(path), a["path"])
        assert os.path.exists(f), a["path"]
    # Stage pairs exist for every boundary and batch.
    for bd in (1, 2, 3):
        for batch in (1, 8):
            assert any(
                a["role"] == "stageA" and a["boundary"] == bd and a["batch"] == batch
                for a in m["artifacts"]
            )
    ts = m["testset"]
    imgs = os.path.join(os.path.dirname(path), ts["images"])
    assert os.path.getsize(imgs) == ts["count"] * int(np.prod(ts["image_shape"])) * 4
