"""L2 model tests: shapes, segment composition, quantization, training."""

import jax
import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest

from compile import model
from compile.kernels import ref

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def params():
    return model.init_params(KEY)


@pytest.fixture(scope="module")
def data():
    return model.make_dataset(256, 128, seed=3)


def test_param_count_matches_rust_zoo(params):
    # rust/src/zoo/tiny.rs asserts the same total.
    assert model.param_count(params) == 448 + 4640 + 18496 + 10250


def test_forward_shapes(params):
    x = jnp.zeros((5, *model.INPUT_SHAPE))
    y = model.forward(params, x)
    assert y.shape == (5, model.NUM_CLASSES)


def test_boundary_shapes(params):
    x = jnp.zeros((2, *model.INPUT_SHAPE))
    for bd, shape in model.BOUNDARY_SHAPES.items():
        h = model.forward_blocks(params, x, 0, bd)
        assert h.shape == (2, *shape), f"boundary {bd}"


def test_segment_composition_equals_full(params):
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, *model.INPUT_SHAPE)).astype(np.float32))
    full = model.forward(params, x)
    for bd in (1, 2, 3):
        h = model.forward_blocks(params, x, 0, bd)
        y = model.forward_blocks(params, h, bd, model.NUM_BLOCKS)
        npt.assert_allclose(np.asarray(y), np.asarray(full), rtol=1e-5, atol=1e-5)


def test_pallas_path_matches_ref_path(params):
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, *model.INPUT_SHAPE)).astype(np.float32))
    a = model.forward(params, x, use_pallas=True)
    b = model.forward(params, x, use_pallas=False)
    npt.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_quantized_forward_differs_but_correlates(params, data):
    (x, _), _ = data
    x = x[:16]
    scales = model.calibrate(params, x, 8)
    y = model.forward(params, x)
    yq = model.forward(params, x, bits=8, scales=scales)
    assert not np.allclose(np.asarray(y), np.asarray(yq))
    # Predictions mostly agree at 8 bits.
    agree = np.mean(np.argmax(np.asarray(y), 1) == np.argmax(np.asarray(yq), 1))
    assert agree > 0.7


def test_calibration_covers_all_sites(params, data):
    (x, _), _ = data
    scales = model.calibrate(params, x[:32], 8)
    expected = {"input", "conv0.w", "conv1.w", "conv2.w", "fc.w", "act0", "act1", "act2", "act3"}
    assert set(scales) == expected
    assert all(s > 0 for s in scales.values())


def test_ste_gradient_passes_through():
    x = jnp.asarray([0.3, -0.7, 1.2])
    g = jax.grad(lambda t: jnp.sum(model.ste_fake_quant(t, 8, 0.1)))(x)
    npt.assert_allclose(np.asarray(g), np.ones(3))


def test_training_reduces_loss(data):
    train, _ = data
    p = model.init_params(jax.random.PRNGKey(7))
    p, losses = model.train(p, train, steps=30, batch=64)
    assert losses[-1] < losses[0]


def test_dataset_determinism():
    a = model.make_dataset(64, 32, seed=5)
    b = model.make_dataset(64, 32, seed=5)
    npt.assert_array_equal(np.asarray(a[0][0]), np.asarray(b[0][0]))
    npt.assert_array_equal(np.asarray(a[1][1]), np.asarray(b[1][1]))
    c = model.make_dataset(64, 32, seed=6)
    assert not np.allclose(np.asarray(a[0][0]), np.asarray(c[0][0]))


def test_dataset_is_balanced_enough():
    (_, y), _ = model.make_dataset(2000, 10, seed=0)
    counts = np.bincount(np.asarray(y), minlength=10)
    assert counts.min() > 100


def test_evaluate_untrained_is_chance_level(data):
    _, test = data
    p = model.init_params(jax.random.PRNGKey(9))
    acc = model.evaluate(p, test)
    assert acc < 35.0  # 10 classes, untrained
