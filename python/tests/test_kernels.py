"""L1 kernel correctness: Pallas vs pure-jnp reference (pytest + hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d_im2col, fake_quant, quant_matmul, ref, vmem_report

RNG = np.random.default_rng(1234)


def randn(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------

class TestQuantMatmul:
    def test_plain_matmul_matches_ref(self):
        a, b, bias = randn(64, 48), randn(48, 32), randn(32)
        y = quant_matmul(a, b, bias, block_m=32, block_n=16, block_k=16)
        npt.assert_allclose(np.asarray(y), np.asarray(ref.matmul(a, b) + bias[None, :]),
                            rtol=1e-5, atol=1e-5)

    def test_fused_quant_matches_ref(self):
        a, b, bias = randn(33, 29), randn(29, 17), randn(17)
        yr = ref.matmul(a, b) + bias[None, :]
        s = float(ref.calibrate_scale(yr, 8))
        y = quant_matmul(a, b, bias, scale=s, bits=8, block_m=16, block_n=16, block_k=16)
        npt.assert_allclose(np.asarray(y),
                            np.asarray(ref.matmul_bias_quant(a, b, bias, 8, s)),
                            rtol=1e-5, atol=1e-5)

    def test_single_block(self):
        a, b, bias = randn(8, 8), randn(8, 8), randn(8)
        y = quant_matmul(a, b, bias, block_m=128, block_n=128, block_k=128)
        npt.assert_allclose(np.asarray(y), np.asarray(a @ b + bias[None, :]),
                            rtol=1e-5, atol=1e-5)

    def test_quantized_output_is_on_grid(self):
        a, b, bias = randn(16, 16), randn(16, 16), randn(16)
        s = 0.125
        y = np.asarray(quant_matmul(a, b, bias, scale=s, bits=8,
                                    block_m=8, block_n=8, block_k=8))
        q = y / s
        npt.assert_allclose(q, np.round(q), atol=1e-4)
        assert q.max() <= 127.0 and q.min() >= -128.0

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 70),
        k=st.integers(1, 70),
        n=st.integers(1, 70),
        bm=st.sampled_from([8, 16, 32]),
        bk=st.sampled_from([8, 16, 32]),
        bn=st.sampled_from([8, 16, 32]),
    )
    def test_hypothesis_shapes(self, m, k, n, bm, bk, bn):
        rng = np.random.default_rng(m * 10007 + k * 101 + n)
        a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        bias = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        y = quant_matmul(a, b, bias, block_m=bm, block_n=bn, block_k=bk)
        npt.assert_allclose(np.asarray(y), np.asarray(a @ b) + np.asarray(bias)[None, :],
                            rtol=2e-4, atol=2e-4)

    @settings(max_examples=10, deadline=None)
    @given(bits=st.sampled_from([4, 6, 8, 16]))
    def test_hypothesis_bit_widths(self, bits):
        a, b, bias = randn(24, 24), randn(24, 24), randn(24)
        yr = ref.matmul(a, b) + bias[None, :]
        s = float(ref.calibrate_scale(yr, bits))
        y = quant_matmul(a, b, bias, scale=s, bits=bits, block_m=8, block_n=8, block_k=8)
        npt.assert_allclose(np.asarray(y),
                            np.asarray(ref.fake_quant(yr, bits, s)), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------

class TestFakeQuant:
    def test_matches_ref(self):
        x = randn(37, 13)
        y = fake_quant(x, 8, 0.05)
        npt.assert_allclose(np.asarray(y), np.asarray(ref.fake_quant(x, 8, 0.05)),
                            rtol=1e-6, atol=1e-6)

    def test_idempotent(self):
        x = randn(100)
        once = fake_quant(x, 8, 0.1)
        twice = fake_quant(once, 8, 0.1)
        npt.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-6)

    def test_16bit_nearly_identity(self):
        x = randn(64, 64)
        s = float(ref.calibrate_scale(x, 16))
        y = fake_quant(x, 16, s)
        npt.assert_allclose(np.asarray(y), np.asarray(x), atol=2 * s)

    def test_clipping_at_range(self):
        x = jnp.asarray(np.array([10.0, -10.0, 0.0], np.float32))
        y = np.asarray(fake_quant(x, 8, 0.01))
        assert y[0] == pytest.approx(127 * 0.01)
        assert y[1] == pytest.approx(-128 * 0.01)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 3000),
        bits=st.sampled_from([4, 8, 16]),
        block=st.sampled_from([64, 256, 1024]),
    )
    def test_hypothesis_sizes(self, n, bits, block):
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        s = float(ref.calibrate_scale(x, bits))
        y = fake_quant(x, bits, s, block=block)
        # Values landing exactly on a .5 grid tie may round differently
        # between the two lowering paths (1-ULP f32 effects): allow one
        # quantization step of absolute difference.
        npt.assert_allclose(np.asarray(y), np.asarray(ref.fake_quant(x, bits, s)),
                            rtol=1e-5, atol=1.01 * s)


# ---------------------------------------------------------------------------
# conv2d_im2col
# ---------------------------------------------------------------------------

class TestConvIm2col:
    def test_matches_lax_conv(self):
        x, w, b = randn(2, 3, 16, 16), randn(8, 3, 3, 3), randn(8)
        y = conv2d_im2col(x, w, b)
        npt.assert_allclose(np.asarray(y), np.asarray(ref.conv2d(x, w, b)),
                            rtol=1e-4, atol=1e-4)

    def test_stride_2(self):
        x, w, b = randn(1, 4, 17, 17), randn(6, 4, 3, 3), randn(6)
        y = conv2d_im2col(x, w, b, stride=2, padding=1)
        yr = ref.conv2d(x, w, b, stride=2, padding=1)
        assert y.shape == yr.shape == (1, 6, 9, 9)
        npt.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)

    def test_quantized_conv(self):
        x, w, b = randn(1, 3, 8, 8), randn(4, 3, 3, 3), randn(4)
        yr = ref.conv2d(x, w, b)
        s = float(ref.calibrate_scale(yr, 8))
        y = conv2d_im2col(x, w, b, bits=8, scale=s)
        npt.assert_allclose(np.asarray(y), np.asarray(ref.fake_quant(yr, 8, s)),
                            rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(1, 3),
        c=st.integers(1, 5),
        o=st.integers(1, 6),
        hw=st.integers(4, 12),
        k=st.sampled_from([1, 3, 5]),
    )
    def test_hypothesis_conv_shapes(self, n, c, o, hw, k):
        rng = np.random.default_rng(n * 1000 + c * 100 + o * 10 + hw + k)
        pad = (k - 1) // 2
        x = jnp.asarray(rng.normal(size=(n, c, hw, hw)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(o, c, k, k)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(o,)).astype(np.float32))
        y = conv2d_im2col(x, w, b, padding=pad)
        npt.assert_allclose(np.asarray(y), np.asarray(ref.conv2d(x, w, b, padding=pad)),
                            rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# structural / §Perf helpers
# ---------------------------------------------------------------------------

class TestVmemReport:
    def test_default_blocking_fits_vmem(self):
        # One step of the default 128^3 blocking must fit in 16 MiB VMEM.
        bytes_, mxu = vmem_report(1024, 1024, 1024)
        assert bytes_ < 16 * 1024 * 1024
        assert mxu == 1.0

    def test_small_problem_underutilizes(self):
        _, mxu = vmem_report(8, 8, 8)
        assert mxu < 0.01

    def test_footprint_scales_with_blocks(self):
        small, _ = vmem_report(1024, 1024, 1024, 32, 32, 32)
        big, _ = vmem_report(1024, 1024, 1024, 256, 256, 256)
        assert big > small


class TestLoweringToHlo:
    def test_pallas_kernel_lowers_to_plain_hlo(self):
        """The AOT contract: interpret-mode Pallas lowers to HLO the CPU
        PJRT client can execute (no Mosaic custom-calls)."""
        from jax._src.lib import xla_client as xc

        def fn(a, b, bias):
            return (quant_matmul(a, b, bias, block_m=8, block_n=8, block_k=8),)

        spec = jax.ShapeDtypeStruct((16, 16), jnp.float32)
        bspec = jax.ShapeDtypeStruct((16,), jnp.float32)
        lowered = jax.jit(fn).lower(spec, spec, bspec)
        mlir_mod = lowered.compiler_ir("stablehlo")
        comp = xc._xla.mlir.mlir_module_to_xla_computation(
            str(mlir_mod), use_tuple_args=False, return_tuple=True
        )
        text = comp.as_hlo_text()
        assert "custom-call" not in text.lower() or "Mosaic" not in text
        assert "ENTRY" in text
