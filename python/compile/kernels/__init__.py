"""L1: Pallas kernels for the partitioned-inference compute hot-spot.

`quant_matmul` / `conv2d_im2col` / `fake_quant` are the Pallas
implementations (interpret=True, CPU-executable HLO); `ref` holds the
pure-jnp oracles pytest checks them against.
"""

from . import ref
from .quant_matmul import conv2d_im2col, fake_quant, quant_matmul, vmem_report

__all__ = [
    "ref",
    "conv2d_im2col",
    "fake_quant",
    "quant_matmul",
    "vmem_report",
]
