"""L1 Pallas kernels: tiled matmul with fused bias + fake quantization.

The inference hot-spot of the partitioned CNN is convolution lowered to
an im2col matmul. The Pallas kernel tiles the (M, K) x (K, N) product
into VMEM-resident blocks, accumulates over the K grid axis in the
output tile, and fuses the bias add and the symmetric fake-quantization
of the output (the operation the embedded accelerators of the paper
perform in their quantized datapaths).

TPU adaptation (DESIGN.md §Hardware-Adaptation): block shapes default to
(128, 128, 128) — MXU-aligned (multiples of (8, 128) for f32) — and the
grid walks K innermost so the output tile stays resident in VMEM while
partial products accumulate (the VMEM analogue of the accelerators'
output-stationary register-file accumulation). `interpret=True`
everywhere: the CPU PJRT client cannot execute Mosaic custom-calls, and
the AOT bridge requires plain-HLO lowering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _quant_matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nsteps, bits, scale):
    """One (bm, bn) output tile; grid axis 2 runs over K blocks."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ w_ref[...]

    @pl.when(k == nsteps - 1)
    def _finish():
        y = o_ref[...] + b_ref[...]
        if bits is not None:
            lo = -(2.0 ** (bits - 1))
            hi = 2.0 ** (bits - 1) - 1.0
            y = jnp.clip(jnp.round(y / scale), lo, hi) * scale
        o_ref[...] = y


def _pad_to(x, rows, cols):
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


@functools.partial(
    jax.jit, static_argnames=("scale", "bits", "block_m", "block_n", "block_k")
)
def quant_matmul(x, w, b, scale=1.0, bits=None, block_m=128, block_n=128, block_k=128):
    """(M, K) @ (K, N) + b with optional fused fake quantization.

    Shapes are padded up to block multiples; the valid region is sliced
    back out, so arbitrary M/K/N are supported.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert b.shape == (n,), f"bias shape {b.shape}"

    bm, bn, bk = (min(block_m, m), min(block_n, n), min(block_k, k))
    mp, np_, kp = (-m % bm + m, -n % bn + n, -k % bk + k)
    xp = _pad_to(x, mp, kp)
    wp = _pad_to(w, kp, np_)
    bp = jnp.pad(b, (0, np_ - n))[None, :]

    nsteps = kp // bk
    grid = (mp // bm, np_ // bn, nsteps)
    out = pl.pallas_call(
        functools.partial(
            _quant_matmul_kernel, nsteps=nsteps, bits=bits, scale=scale
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def _fake_quant_kernel(x_ref, o_ref, *, bits, scale):
    lo = -(2.0 ** (bits - 1))
    hi = 2.0 ** (bits - 1) - 1.0
    o_ref[...] = jnp.clip(jnp.round(x_ref[...] / scale), lo, hi) * scale


@functools.partial(jax.jit, static_argnames=("bits", "scale", "block"))
def fake_quant(x, bits, scale, block=1024):
    """Elementwise symmetric fake quantization as a Pallas kernel."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    bs = min(block, n)
    npad = -n % bs + n
    xp = jnp.pad(flat, (0, npad - n)).reshape(npad // bs, bs)
    out = pl.pallas_call(
        functools.partial(_fake_quant_kernel, bits=bits, scale=scale),
        grid=(npad // bs,),
        in_specs=[pl.BlockSpec((1, bs), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad // bs, bs), x.dtype),
        interpret=True,
    )(xp)
    return out.reshape(-1)[:n].reshape(x.shape)


def conv2d_im2col(x, w, b, stride=1, padding=1, bits=None, scale=1.0):
    """Convolution via im2col + the Pallas quant-matmul hot-spot.

    x: (N, C, H, W); w: (O, I, KH, KW); b: (O,). Returns (N, O, OH, OW).
    """
    n = x.shape[0]
    o, _, kh, kw = w.shape
    cols, (oh, ow) = ref.im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(o, -1).T  # (C*KH*KW, O)
    y = quant_matmul(cols, wmat, b, scale=scale, bits=bits)
    return y.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)


def vmem_report(m, k, n, block_m=128, block_n=128, block_k=128, dtype_bytes=4):
    """Static VMEM footprint estimate for one grid step (§Perf, L1).

    Returns (bytes_per_step, mxu_utilization_estimate) for the chosen
    blocking on a 128x128 MXU.
    """
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    tiles = (bm * bk + bk * bn + bm * bn + bn) * dtype_bytes
    # MXU issue efficiency: fraction of the 128-lane systolic array used.
    mxu = min(bm, 128) / 128.0 * min(bn, 128) / 128.0 * min(bk, 128) / 128.0
    return tiles, mxu
