"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness).

Every kernel in this package has a reference implementation here built
only from documented jax.numpy / lax primitives. pytest (and hypothesis)
assert allclose between kernel and reference across shapes and bit
widths; the AOT path refuses to export if the self-check fails.
"""

import jax
import jax.numpy as jnp


def fake_quant(x, bits, scale):
    """Symmetric per-tensor fake quantization.

    q = clip(round(x / scale), -2^(b-1), 2^(b-1) - 1) * scale
    """
    lo = -(2.0 ** (bits - 1))
    hi = 2.0 ** (bits - 1) - 1.0
    q = jnp.clip(jnp.round(x / scale), lo, hi)
    return q * scale


def calibrate_scale(x, bits):
    """Max-abs calibration: scale so the observed range maps onto the grid."""
    hi = 2.0 ** (bits - 1) - 1.0
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    return amax / hi


def matmul(a, b):
    """Plain f32 matmul, (M, K) @ (K, N) -> (M, N)."""
    return jnp.matmul(a, b)


def matmul_bias_quant(a, b, bias, bits, scale):
    """The fused hot-spot: matmul + bias + fake-quantized output."""
    y = jnp.matmul(a, b) + bias[None, :]
    return fake_quant(y, bits, scale)


def conv2d(x, w, b, stride=1, padding=1):
    """NCHW conv with OIHW weights + bias. Reference for the im2col path."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def maxpool2(x):
    """2x2/2 max pooling, NCHW."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2),
        padding="VALID",
    )


def im2col(x, kh, kw, stride=1, padding=1):
    """Extract conv patches: (N, C, H, W) -> (N*OH*OW, C*KH*KW)."""
    n = x.shape[0]
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (N, C*KH*KW, OH, OW)
    ckk = patches.shape[1]
    oh, ow = patches.shape[2], patches.shape[3]
    cols = patches.transpose(0, 2, 3, 1).reshape(n * oh * ow, ckk)
    return cols, (oh, ow)
