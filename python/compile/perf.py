"""§Perf analysis for L1 (Pallas kernel) and L2 (JAX graph).

L1: static VMEM footprint + MXU-utilization estimates per conv layer of
the executable model under the kernel's blocking (interpret=True gives
CPU-numpy wallclock only, which is *not* a TPU proxy — the structural
estimate is the optimization target, per DESIGN.md).

L2: HLO op histograms of the exported artifacts — checks that lowering
fused the quant arithmetic (no stray transposes/copies beyond the
expected im2col data movement) and reports artifact sizes.

    cd python && python -m compile.perf [--artifacts ../artifacts]
"""

import argparse
import collections
import os
import re

from . import model
from .kernels import vmem_report


def l1_report(block=128):
    print(f"[L1] quant_matmul blocking {block}^3, f32 (per grid step)")
    print(f"{'conv':<8} {'M':>7} {'K':>6} {'N':>5} {'vmem':>10} {'mxu est':>8}")
    h = w = model.INPUT_SHAPE[1]
    c_in = model.INPUT_SHAPE[0]
    for i, c_out in enumerate(model.CHANNELS):
        m_dim = h * w  # batch 1: one patch row per output pixel
        k_dim = c_in * 9
        bytes_, mxu = vmem_report(m_dim, k_dim, c_out, block, block, block)
        print(f"conv{i:<4} {m_dim:>7} {k_dim:>6} {c_out:>5} {bytes_/1024:>8.1f}KB {mxu:>8.3f}")
        h //= 2
        w //= 2
        c_in = c_out
    total_vmem, _ = vmem_report(1024, 1024, 1024, block, block, block)
    print(f"[L1] upper-bound step footprint {total_vmem/1024:.0f} KiB "
          f"(16 MiB VMEM budget -> {100*total_vmem/(16<<20):.1f}% used)")


def l2_report(artifacts_dir):
    if not os.path.isdir(artifacts_dir):
        print(f"[L2] no artifacts at {artifacts_dir}; run `make artifacts`")
        return
    op_re = re.compile(r"^\s+\S+ = \S+ ([a-z0-9-]+)\(")
    for name in ("full_fp32_n1", "full_q8_n1", "stageA_q16_bd2_n8"):
        path = os.path.join(artifacts_dir, f"{name}.hlo.txt")
        if not os.path.exists(path):
            continue
        ops = collections.Counter()
        with open(path) as f:
            for line in f:
                mm = op_re.match(line)
                if mm:
                    ops[mm.group(1)] += 1
        size = os.path.getsize(path)
        top = ", ".join(f"{k}x{v}" for k, v in ops.most_common(8))
        print(f"[L2] {name}: {size//1024} KiB, {sum(ops.values())} ops ({top})")
        # Fusion sanity: interpret-mode pallas introduces loop scaffolding
        # (while/dynamic-update-slice); the quant math itself must appear
        # as plain elementwise ops, not custom calls.
        assert ops.get("custom-call", 0) == 0, f"{name}: custom-call leaked into HLO"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--block", type=int, default=128)
    args = ap.parse_args()
    l1_report(args.block)
    print()
    l2_report(args.artifacts)


if __name__ == "__main__":
    main()
