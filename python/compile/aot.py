"""AOT bridge: train the tiny CNN, quantize it, and export every
partition segment as HLO **text** for the Rust PJRT runtime.

Run once by `make artifacts` (python never executes on the request
path):

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO text, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. Trained weights are baked into
the HLO as constants, so each artifact is a single-input function.

Exports, per batch size in {1, 8}:
  * `full_fp32`                    — whole network
  * `stageA_fp32_bd{1,2,3}`        — blocks [0, b) (platform A side)
  * `stageB_fp32_bd{1,2,3}`        — blocks [b, 4) (platform B side)
  * `stageA_q16_bd{b}` / `stageB_q8_bd{b}` — the EYR(16b)/SMB(8b)
    mixed-precision assignment of the paper's two-platform system
  * `full_q8`, `full_q16`          — single-platform quantized references
plus `manifest.json`, the held-out test set (`testset_*.bin`) and the
training/accuracy metadata the Rust side reports against.
"""

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_fn(fn, example, path):
    lowered = jax.jit(fn).lower(example)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return os.path.getsize(path)


def segment_fn(params, start, stop, bits, scales):
    """Close over trained params: single-input segment function.

    The export path routes convs through the L1 Pallas kernel so the
    hot-spot's lowering lands in the artifact HLO.
    """

    def fn(x):
        return (
            model.forward_blocks(
                params,
                x,
                start=start,
                stop=stop,
                bits=bits,
                scales=scales,
                use_pallas=True,
            ),
        )

    return fn


def self_check(params, scales8, data):
    """Refuse to export if the Pallas path diverges from the reference."""
    x = data[0][:4]
    for bits, scales in ((None, None), (8, scales8)):
        a = model.forward(params, x, bits=bits, scales=scales, use_pallas=True)
        b = model.forward(params, x, bits=bits, scales=scales, use_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
    # Stage composition == full network.
    for bd in (1, 2, 3):
        h = model.forward_blocks(params, x, 0, bd, use_pallas=True)
        y = model.forward_blocks(params, h, bd, model.NUM_BLOCKS, use_pallas=True)
        full = model.forward(params, x, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(full), rtol=2e-4, atol=2e-4)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--qat-steps", type=int, default=150)
    ap.add_argument("--quick", action="store_true", help="tiny training run for CI")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.quick:
        args.train_steps, args.qat_steps = 40, 20

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()

    # ---- train ---------------------------------------------------------
    train_data, test_data = model.make_dataset(4096, 1024, seed=args.seed)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    params, losses = model.train(params, train_data, steps=args.train_steps)
    acc_fp32 = model.evaluate(params, test_data)
    print(f"[aot] fp32 trained: loss {losses[0]:.3f}->{losses[-1]:.4f} "
          f"top1 {acc_fp32:.2f}% ({time.time()-t0:.0f}s)", flush=True)

    # ---- calibrate + PTQ + QAT ----------------------------------------
    calib = train_data[0][:256]
    scales8 = model.calibrate(params, calib, 8)
    scales16 = model.calibrate(params, calib, 16)
    acc_ptq8 = model.evaluate(params, test_data, bits=8, scales=scales8)
    acc_ptq16 = model.evaluate(params, test_data, bits=16, scales=scales16)
    qat_params, _ = model.train(
        dict(params), train_data, steps=args.qat_steps, bits=8, scales=scales8, lr=2e-4
    )
    acc_qat8 = model.evaluate(qat_params, test_data, bits=8, scales=scales8)
    print(f"[aot] ptq8 {acc_ptq8:.2f}% ptq16 {acc_ptq16:.2f}% qat8 {acc_qat8:.2f}%",
          flush=True)

    self_check(params, scales8, train_data)

    # ---- export --------------------------------------------------------
    artifacts = []

    def emit(name, fn, batch, in_shape, out_shape, **meta):
        path = f"{name}.hlo.txt"
        example = jax.ShapeDtypeStruct((batch, *in_shape), jnp.float32)
        size = export_fn(fn, example, os.path.join(args.out, path))
        artifacts.append(
            {
                "name": name,
                "path": path,
                "batch": batch,
                "input_shape": list(in_shape),
                "output_shape": list(out_shape),
                "bytes": size,
                **meta,
            }
        )
        print(f"[aot]   wrote {path} ({size//1024} KiB)", flush=True)

    in_shape = model.INPUT_SHAPE
    out_shape = (model.NUM_CLASSES,)
    for batch in (1, 8):
        emit(
            f"full_fp32_n{batch}",
            segment_fn(params, 0, model.NUM_BLOCKS, None, None),
            batch, in_shape, out_shape, role="full", bits=None, boundary=None,
        )
        emit(
            f"full_q8_n{batch}",
            segment_fn(qat_params, 0, model.NUM_BLOCKS, 8, scales8),
            batch, in_shape, out_shape, role="full", bits=8, boundary=None,
        )
        emit(
            f"full_q16_n{batch}",
            segment_fn(params, 0, model.NUM_BLOCKS, 16, scales16),
            batch, in_shape, out_shape, role="full", bits=16, boundary=None,
        )
        for bd in (1, 2, 3):
            mid = model.BOUNDARY_SHAPES[bd]
            emit(
                f"stageA_fp32_bd{bd}_n{batch}",
                segment_fn(params, 0, bd, None, None),
                batch, in_shape, mid, role="stageA", bits=None, boundary=bd,
            )
            emit(
                f"stageB_fp32_bd{bd}_n{batch}",
                segment_fn(params, bd, model.NUM_BLOCKS, None, None),
                batch, mid, out_shape, role="stageB", bits=None, boundary=bd,
            )
            emit(
                f"stageA_q16_bd{bd}_n{batch}",
                segment_fn(params, 0, bd, 16, scales16),
                batch, in_shape, mid, role="stageA", bits=16, boundary=bd,
            )
            emit(
                f"stageB_q8_bd{bd}_n{batch}",
                segment_fn(qat_params, bd, model.NUM_BLOCKS, 8, scales8),
                batch, mid, out_shape, role="stageB", bits=8, boundary=bd,
            )

    # ---- test set ------------------------------------------------------
    x_test, y_test = test_data
    np.asarray(x_test, dtype=np.float32).tofile(os.path.join(args.out, "testset_images.bin"))
    np.asarray(y_test, dtype=np.uint8).tofile(os.path.join(args.out, "testset_labels.bin"))

    manifest = {
        "model": "tiny_cnn",
        "input_shape": list(in_shape),
        "classes": model.NUM_CLASSES,
        "param_count": model.param_count(params),
        "boundaries": {
            str(b): {"position": model.BOUNDARY_POSITIONS[b],
                     "shape": list(model.BOUNDARY_SHAPES[b])}
            for b in (1, 2, 3)
        },
        "accuracy": {
            "fp32": acc_fp32,
            "ptq8": acc_ptq8,
            "ptq16": acc_ptq16,
            "qat8": acc_qat8,
        },
        "train": {
            "steps": args.train_steps,
            "qat_steps": args.qat_steps,
            "seed": args.seed,
            "final_loss": losses[-1],
        },
        "testset": {
            "images": "testset_images.bin",
            "labels": "testset_labels.bin",
            "count": int(x_test.shape[0]),
            "image_shape": list(in_shape),
        },
        "artifacts": artifacts,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] done: {len(artifacts)} artifacts in {args.out} "
          f"({time.time()-t0:.0f}s total)", flush=True)


if __name__ == "__main__":
    main()
