"""L2: the executable tiny CNN in JAX (build-time only).

Layer-for-layer mirror of `rust/src/zoo/tiny.rs` — 3×32×32 input, three
conv(3x3, pad 1) → ReLU → maxpool(2) blocks with 16/32/64 channels, then
flatten → linear(10). The forward pass is segmentable at the block
boundaries, which map onto the Rust explorer's schedule positions
(3, 6, 9); `python/compile/aot.py` exports each segment as an HLO
artifact that the Rust runtime loads.

The export path routes every convolution through the L1 Pallas
`conv2d_im2col` kernel so the hot-spot lowers into the artifact HLO; the
training path uses the jnp reference ops (pallas interpret mode is too
slow to train through) — pytest asserts both paths agree.

Quantization: symmetric per-tensor fake quant on weights and
activations, max-abs calibrated; QAT uses a straight-through estimator.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv2d_im2col, ref

CHANNELS = (16, 32, 64)
INPUT_SHAPE = (3, 32, 32)
NUM_CLASSES = 10
NUM_BLOCKS = 4  # three conv blocks + classifier block
# Rust schedule positions of the block boundaries (after each MaxPool).
BOUNDARY_POSITIONS = {1: 3, 2: 6, 3: 9}
# Feature-map shape at each boundary.
BOUNDARY_SHAPES = {1: (16, 16, 16), 2: (32, 8, 8), 3: (64, 4, 4)}


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(key):
    """He-initialized parameters, a dict of {w, b} leaves per layer."""
    keys = jax.random.split(key, 4)
    params = {}
    c_in = INPUT_SHAPE[0]
    for i, c_out in enumerate(CHANNELS):
        fan_in = c_in * 9
        params[f"conv{i}"] = {
            "w": jax.random.normal(keys[i], (c_out, c_in, 3, 3), jnp.float32)
            * jnp.sqrt(2.0 / fan_in),
            "b": jnp.zeros((c_out,), jnp.float32),
        }
        c_in = c_out
    feat = CHANNELS[-1] * 4 * 4
    params["fc"] = {
        "w": jax.random.normal(keys[3], (feat, NUM_CLASSES), jnp.float32)
        * jnp.sqrt(1.0 / feat),
        "b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }
    return params


def param_count(params):
    return sum(int(np.prod(v.shape)) for layer in params.values() for v in layer.values())


# --------------------------------------------------------------------------
# Quantization helpers
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def ste_fake_quant(x, bits, scale):
    """Fake quantization with a straight-through gradient (QAT)."""
    return ref.fake_quant(x, bits, scale)


def _ste_fwd(x, bits, scale):
    return ref.fake_quant(x, bits, scale), None


def _ste_bwd(bits, scale, _res, g):
    return (g,)


ste_fake_quant.defvjp(_ste_fwd, _ste_bwd)


def calibrate(params, x, bits):
    """Max-abs activation/weight scales from a calibration batch.

    Returns {site: scale} for weight sites `conv{i}.w`, `fc.w` and
    activation sites `act{block}` (block outputs) plus `input`.
    """
    scales = {"input": float(ref.calibrate_scale(x, bits))}
    for i in range(3):
        scales[f"conv{i}.w"] = float(ref.calibrate_scale(params[f"conv{i}"]["w"], bits))
    scales["fc.w"] = float(ref.calibrate_scale(params["fc"]["w"], bits))
    h = x
    for i in range(3):
        h = ref.conv2d(h, params[f"conv{i}"]["w"], params[f"conv{i}"]["b"])
        h = jax.nn.relu(h)
        h = ref.maxpool2(h)
        scales[f"act{i}"] = float(ref.calibrate_scale(h, bits))
    logits = h.reshape(h.shape[0], -1) @ params["fc"]["w"] + params["fc"]["b"]
    scales["act3"] = float(ref.calibrate_scale(logits, bits))
    return scales


# --------------------------------------------------------------------------
# Forward (segmentable)
# --------------------------------------------------------------------------

def _quant(x, bits, scale, ste):
    if bits is None:
        return x
    if ste:
        return ste_fake_quant(x, bits, scale)
    return ref.fake_quant(x, bits, scale)


def forward_blocks(
    params,
    x,
    start=0,
    stop=NUM_BLOCKS,
    bits=None,
    scales=None,
    use_pallas=False,
    ste=False,
):
    """Run blocks [start, stop). Block i<3 = conv→relu→pool; block 3 =
    flatten→fc. `bits`/`scales` enable fake quantization of weights and
    block outputs; `use_pallas` routes convs through the L1 kernel."""
    assert 0 <= start < stop <= NUM_BLOCKS
    h = x
    if bits is not None and start == 0:
        h = _quant(h, bits, scales["input"], ste)
    for i in range(start, min(stop, 3)):
        w = params[f"conv{i}"]["w"]
        b = params[f"conv{i}"]["b"]
        if bits is not None:
            w = _quant(w, bits, scales[f"conv{i}.w"], ste)
        if use_pallas:
            h = conv2d_im2col(h, w, b)
        else:
            h = ref.conv2d(h, w, b)
        h = jax.nn.relu(h)
        h = ref.maxpool2(h)
        if bits is not None:
            h = _quant(h, bits, scales[f"act{i}"], ste)
    if stop == NUM_BLOCKS:
        w = params["fc"]["w"]
        if bits is not None:
            w = _quant(w, bits, scales["fc.w"], ste)
        h = h.reshape(h.shape[0], -1) @ w + params["fc"]["b"]
    return h


def forward(params, x, bits=None, scales=None, use_pallas=False, ste=False):
    return forward_blocks(params, x, 0, NUM_BLOCKS, bits, scales, use_pallas, ste)


# --------------------------------------------------------------------------
# Synthetic dataset (ImageNet stand-in; see DESIGN.md substitutions)
# --------------------------------------------------------------------------

def make_dataset(n_train, n_test, seed=0):
    """10-class textured-blob images: class template + noise, normalized.

    Deterministic in `seed`. Hard enough that an untrained net scores
    ~10% and the trained tiny CNN reaches ≳90%, with a measurable
    quantization gap — the properties the accuracy explorer needs.
    """
    rng = np.random.default_rng(seed)
    templates = rng.normal(size=(NUM_CLASSES, *INPUT_SHAPE)).astype(np.float32)
    # Low-pass the templates so conv features are learnable.
    for c in range(NUM_CLASSES):
        for ch in range(INPUT_SHAPE[0]):
            t = templates[c, ch]
            t = 0.25 * (np.roll(t, 1, 0) + np.roll(t, -1, 0) + np.roll(t, 1, 1) + np.roll(t, -1, 1))
            templates[c, ch] = t

    def gen(n):
        labels = rng.integers(0, NUM_CLASSES, size=n)
        noise = rng.normal(scale=2.2, size=(n, *INPUT_SHAPE)).astype(np.float32)
        # Random per-image gain/offset plus rare outlier pixels: makes
        # max-abs calibration imperfect, so quantization actually costs
        # accuracy (as it does on ImageNet).
        gain = rng.uniform(0.6, 1.4, size=(n, 1, 1, 1)).astype(np.float32)
        imgs = templates[labels] * gain + noise
        outliers = rng.random(size=imgs.shape) < 0.002
        imgs = np.where(outliers, imgs * 8.0, imgs).astype(np.float32)
        imgs = (imgs - imgs.mean()) / (imgs.std() + 1e-6)
        return jnp.asarray(imgs), jnp.asarray(labels)

    return gen(n_train), gen(n_test)


# --------------------------------------------------------------------------
# Training (hand-rolled Adam; no optax in this environment)
# --------------------------------------------------------------------------

def loss_fn(params, x, y, bits=None, scales=None):
    logits = forward(params, x, bits=bits, scales=scales, ste=bits is not None)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_step(params, state, grads, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return params, {"m": m, "v": v, "t": t}


def train(params, data, steps, batch=128, lr=1e-3, bits=None, scales=None, seed=1):
    """Adam training loop; with `bits` set this is QAT (STE gradients)."""
    x_all, y_all = data
    n = x_all.shape[0]
    state = adam_init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, x, y, bits=bits, scales=scales)
        )(params)
        params, state = adam_step(params, state, grads, lr=lr)
        return params, state, loss

    rng = np.random.default_rng(seed)
    losses = []
    for _ in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, state, loss = step(params, state, x_all[idx], y_all[idx])
        losses.append(float(loss))
    return params, losses


def evaluate(params, data, bits=None, scales=None, batch=256):
    """Top-1 accuracy in percent."""
    x_all, y_all = data
    correct = 0

    @jax.jit
    def predict(x):
        return jnp.argmax(forward(params, x, bits=bits, scales=scales), axis=1)

    for i in range(0, x_all.shape[0], batch):
        pred = predict(x_all[i : i + batch])
        correct += int(jnp.sum(pred == y_all[i : i + batch]))
    return 100.0 * correct / x_all.shape[0]
