//! Regenerate every table and figure of the paper's evaluation (§V)
//! into `reports/`:
//!
//!     cargo run --release --example paper_figures [--fast] [--cache-dir DIR]
//!
//! Fig 2(a–f) per-model partitioning series, Fig 3 memory analysis,
//! Table II partition histogram. See DESIGN.md's per-experiment index
//! and EXPERIMENTS.md for measured-vs-paper comparisons. With
//! `--cache-dir`, layer costs persist across invocations and a re-run
//! skips the mapper entirely.

use std::path::{Path, PathBuf};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let cache_dir: Option<PathBuf> = args
        .iter()
        .position(|a| a == "--cache-dir")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from);
    let jobs = partir::util::parallel::default_jobs();
    partir::report::paper::generate_all(Path::new("reports"), fast, jobs, cache_dir.as_deref())
}
