//! Regenerate every table and figure of the paper's evaluation (§V)
//! into `reports/`:
//!
//!     cargo run --release --example paper_figures [--fast]
//!
//! Fig 2(a–f) per-model partitioning series, Fig 3 memory analysis,
//! Table II partition histogram. See DESIGN.md's per-experiment index
//! and EXPERIMENTS.md for measured-vs-paper comparisons.

use std::path::Path;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let jobs = partir::util::parallel::default_jobs();
    partir::report::paper::generate_all(Path::new("reports"), fast, jobs)
}
