//! Multi-partitioning over a 4-platform chain (paper §V-C): two
//! Eyeriss-like platforms near the sensor, two Simba-like platforms
//! towards the central unit, all linked by Gigabit Ethernet — e.g. the
//! automotive zonal-gateway topology the paper motivates.
//!
//!     cargo run --release --example multi_platform [model]
//!
//! Prints the NSGA-II Pareto front and the Table II partition histogram
//! for the chosen model (default: efficientnet_b0, the paper's largest
//! beneficiary of >2 partitions).

use partir::config::SystemConfig;
use partir::explorer::{multi::partition_histogram, ExploreRequest};
use partir::report;
use partir::zoo;

fn main() {
    let model = std::env::args().nth(1).unwrap_or_else(|| "efficientnet_b0".into());
    let graph = zoo::build(&model).unwrap_or_else(|| {
        eprintln!("unknown model {model}; available: {:?}", zoo::names());
        std::process::exit(2);
    });
    println!("{}\n", graph.summary());

    let system = SystemConfig::paper_four_platform();
    println!(
        "chain: {} over {}, Pareto metrics: {:?}\n",
        system
            .platforms
            .iter()
            .map(|p| format!("{}({})", p.name, p.accelerator.name))
            .collect::<Vec<_>>()
            .join(" -> "),
        system.link.name,
        system.pareto_metrics.iter().map(|m| m.name()).collect::<Vec<_>>(),
    );

    let ex = ExploreRequest::chain().run(&graph, &system);
    print!("{}", report::render_exploration(&ex, &system));

    let hist = partition_histogram(&ex, system.platforms.len());
    println!("\nTable II row for {model}:");
    println!("  1 partition: {}   2: {}   3: {}   4: {}", hist[0], hist[1], hist[2], hist[3]);
    let multi: usize = hist[1..].iter().sum();
    if multi > 0 {
        println!("  -> {multi} of {} near-optimal schedules split the network", ex.pareto.len());
    }
}
