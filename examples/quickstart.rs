//! Quickstart: partition a small CNN over the paper's two-platform
//! system (Eyeriss-like "EYR" → Gigabit Ethernet → Simba-like "SMB")
//! and print the Pareto-optimal partitioning points.
//!
//!     cargo run --release --example quickstart
//!
//! This exercises the whole public DSE API in ~30 lines: build a model
//! graph, describe the system, explore, inspect the result.

use partir::config::SystemConfig;
use partir::explorer::ExploreRequest;
use partir::report;
use partir::zoo;

fn main() {
    // 1. The workload: any zoo model works; the tiny CNN also has real
    //    AOT artifacts (see the pipeline_serving example).
    let graph = zoo::build("squeezenet1_1").expect("zoo model");
    println!("{}\n", graph.summary());

    // 2. The system: platform A (EYR, 16-bit) feeds platform B (SMB,
    //    8-bit) over Gigabit Ethernet — the paper's §V-A setup.
    let system = SystemConfig::paper_two_platform();

    // 3. Explore: enumerate Definition-1 partitioning points, filter on
    //    memory/link constraints, evaluate latency/energy/throughput/
    //    accuracy per point, and run NSGA-II for the Pareto front.
    let exploration = ExploreRequest::chain().run(&graph, &system);

    // 4. Inspect.
    print!("{}", report::render_exploration(&exploration, &system));
    if let Some((label, gain)) = report::throughput_gain(&exploration) {
        println!("\npipelining at {label} beats the best single platform by {gain:.1}%");
    }
    let favorite = exploration.favorite_metrics().expect("feasible candidate");
    println!(
        "chosen point: {} — {:.2} ms, {:.2} mJ, {:.1} inf/s, top-1 {:.2}%",
        favorite.label,
        favorite.latency_s * 1e3,
        favorite.energy_j * 1e3,
        favorite.throughput,
        favorite.top1
    );
}
