//! End-to-end driver (EXPERIMENTS.md §End-to-end): prove that all three
//! layers compose on a real workload.
//!
//! 1. The L3 explorer partitions the tiny CNN over the EYR→GbE→SMB
//!    system and picks a boundary.
//! 2. The chosen partitioning is instantiated with REAL AOT artifacts
//!    (L2 JAX segments calling the L1 Pallas kernel, compiled to HLO by
//!    `make artifacts`), served as a two-stage pipeline with dynamic
//!    batching over the simulated Gigabit-Ethernet link.
//! 3. Reports measured latency/throughput/top-1 against (a) the
//!    unpartitioned single-platform baseline and (b) the Definition-4
//!    analytical prediction.
//!
//!     make artifacts && cargo run --release --example pipeline_serving

use partir::config::SystemConfig;
use partir::coordinator::{run_pipeline, BatchPolicy, PipelineCfg, StageComputeSpec, StageSpec};
use partir::explorer::ExploreRequest;
use partir::runtime::Manifest;
use partir::zoo;
use std::path::PathBuf;
use std::time::Duration;

const REQUESTS: usize = 256;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from("artifacts");
    let manifest = Manifest::load(&dir)
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    let testset = manifest.load_testset()?;

    // ---- 1. explorer chooses the boundary --------------------------------
    let graph = zoo::tiny_cnn(10);
    let system = SystemConfig::paper_two_platform();
    let ex = ExploreRequest::chain().run(&graph, &system);
    // Only block boundaries have exported artifacts; pick the exported
    // boundary closest to the explorer's best-throughput cut.
    let best_cut = ex
        .candidates
        .iter()
        .filter(|c| c.partitions == 2 && c.feasible())
        .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
        .expect("a pipelined candidate");
    let cut_pos = best_cut.positions[0];
    let boundary = *manifest
        .boundaries
        .iter()
        .min_by_key(|(_, b)| b.position.abs_diff(cut_pos))
        .map(|(k, _)| k)
        .unwrap();
    println!(
        "explorer picked cut after {} (schedule pos {cut_pos}) -> artifact boundary {boundary} \
         (predicted {:.1} inf/s, {:.2} ms)",
        best_cut.label,
        best_cut.throughput,
        best_cut.latency_s * 1e3
    );

    let inputs: Vec<Vec<f32>> =
        (0..REQUESTS).map(|i| testset.image(i % testset.count).to_vec()).collect();
    let cfg = PipelineCfg {
        batch: BatchPolicy::new(8, Duration::from_millis(1)),
        ..Default::default()
    };

    // ---- 2. partitioned pipeline (quantized EYR-16b / SMB-8b) ------------
    let mid_elems: usize = manifest.boundaries[&boundary].shape.iter().product();
    let pick = |role: &str, bits: Option<u32>, bd: Option<usize>| {
        vec![
            manifest.find(role, bits, bd, 1).expect("artifact n1").clone(),
            manifest.find(role, bits, bd, 8).expect("artifact n8").clone(),
        ]
    };
    let partitioned = vec![
        StageSpec {
            name: "A-eyr16".into(),
            compute: StageComputeSpec::Artifacts {
                dir: dir.clone(),
                metas: pick("stageA", Some(16), Some(boundary)),
            },
            out_bytes_per_item: (mid_elems * 2) as u64, // 16-bit feature map
        },
        StageSpec {
            name: "B-smb8".into(),
            compute: StageComputeSpec::Artifacts {
                dir: dir.clone(),
                metas: pick("stageB", Some(8), Some(boundary)),
            },
            out_bytes_per_item: 0,
        },
    ];
    println!("\n=== partitioned (boundary {boundary}, quantized 16b/8b) ===");
    let part = run_pipeline(partitioned, &cfg, inputs.clone());
    print!("{}", part.render());
    let top1 = |r: &partir::coordinator::PipelineReport| {
        100.0
            * r.completions
                .iter()
                .filter(|c| c.prediction == Some(testset.labels[c.id as usize % testset.count] as usize))
                .count() as f64
            / r.completions.len() as f64
    };
    println!("top-1: {:.2}%", top1(&part));

    // ---- 3. unpartitioned baseline (all on one platform, q8) -------------
    println!("\n=== baseline (single platform, q8) ===");
    let single = vec![StageSpec {
        name: "single-q8".into(),
        compute: StageComputeSpec::Artifacts { dir: dir.clone(), metas: pick("full", Some(8), None) },
        out_bytes_per_item: 0,
    }];
    let base = run_pipeline(single, &cfg, inputs);
    print!("{}", base.render());
    println!("top-1: {:.2}%", top1(&base));

    // ---- 4. comparison + Definition-4 prediction --------------------------
    let gain = 100.0 * (part.throughput() - base.throughput()) / base.throughput();
    println!("\npartitioned vs single-platform throughput: {gain:+.1}%");
    // Def 4 with measured stage service rates: th = min(1/d_A, 1/d_link, 1/d_B).
    let rate = |s: &partir::coordinator::StageStats| {
        if s.busy.as_secs_f64() > 0.0 {
            s.items as f64 / s.busy.as_secs_f64()
        } else {
            f64::INFINITY
        }
    };
    let link_rate =
        cfg.link.throughput_ceiling((mid_elems * 2) as u64) * cfg.batch.max_batch as f64;
    let predicted = rate(&part.stages[0]).min(rate(&part.stages[1])).min(link_rate);
    println!(
        "Definition 4 check: min(1/d_A, 1/d_link, 1/d_B) = {predicted:.1} inf/s, measured {:.1} inf/s",
        part.throughput()
    );
    println!(
        "build-time accuracy: fp32 {:.2}% ptq8 {:.2}% qat8 {:.2}%",
        manifest.accuracy.fp32, manifest.accuracy.ptq8, manifest.accuracy.qat8
    );
    Ok(())
}
